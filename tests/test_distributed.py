"""Production shard_map engine tests (8 forced host devices via
subprocess, so the rest of the suite keeps the real single-device CPU)."""

import pytest

pytestmark = pytest.mark.slow


DIST_MATCHES_REFERENCE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.core import qsparse, operators as ops, schedule
from repro.optim import sgd, constant

mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out = 4, 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params_dev = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

inner = sgd()
comp = ShardCompressor(mode="topk", k_frac=0.25)
init_fn, local_step, sync_step = make_dist_steps(
    grad_fn, inner, comp, constant(0.1), mesh, ("data",), specs)

# reference engine with the equivalent per-leaf operator:
# w [16, 8] model-sharded on axis1 -> _pick_axis keeps axis0 (len 16)
# => per-column top-k over 16 with k_frac 0.25 (k=4 per column);
# b [8] has size <= 8 => the engine skips compression (dense).
class ColTopK(ops.CompressionOp):
    def __call__(self, key, x):
        from repro.core.distributed import axis_topk
        if x.size <= 8:
            return x.astype(jnp.float32), jnp.float32(32 * x.size)
        return axis_topk(x, 0.25, 0)
    def gamma(self, d):
        return 0.25

op_ref = ColTopK()
state_ref = qsparse.init(params, inner, R)
step_ref = jax.jit(qsparse.make_step(grad_fn, inner, op_ref, constant(0.1), R),
                   static_argnames=("sync",))

with set_mesh(mesh):
    state = init_fn(params_dev)
    ls, ss = jax.jit(local_step), jax.jit(sync_step)
    key = jax.random.PRNGKey(1)
    H = 4
    for t in range(32):
        key, s1, s2 = jax.random.split(key, 3)
        x = jax.random.normal(s1, (R, 16, d_in))
        y = jnp.einsum("rbi,io->rbo", x, Wtrue)
        sync = (t + 1) % H == 0
        if sync:
            state, loss = ss(state, (x, y), s2)
        else:
            state, loss = ls(state, (x, y), s2)
        state_ref, loss_ref = step_ref(state_ref, (x, y), sync=sync, key=s2)
        np.testing.assert_allclose(float(loss), float(loss_ref),
                                   rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(state.master["w"]),
                               np.asarray(state_ref.master["w"]),
                               rtol=1e-4, atol=1e-5)
print("DIST==REF OK")
"""


def test_dist_engine_matches_reference(subproc):
    out = subproc(DIST_MATCHES_REFERENCE, devices=8)
    assert "DIST==REF OK" in out


ZERO1_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.optim import sgd, constant

mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out = 4, 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

masters = []
for zero1 in (False, True):
    init_fn, local_step, sync_step = make_dist_steps(
        grad_fn, sgd(), ShardCompressor("topk", 0.25), constant(0.1),
        mesh, ("data",), specs, zero1=zero1)
    with set_mesh(mesh):
        state = init_fn(params)
        ls, ss = jax.jit(local_step), jax.jit(sync_step)
        key = jax.random.PRNGKey(1)
        for t in range(16):
            key, s1, s2 = jax.random.split(key, 3)
            x = jax.random.normal(s1, (R, 16, d_in))
            y = jnp.einsum("rbi,io->rbo", x, Wtrue)
            if (t + 1) % 4 == 0:
                state, _ = ss(state, (x, y), s2)
            else:
                state, _ = ls(state, (x, y), s2)
        # gather the (possibly zero1-sharded) master
        w = np.asarray(jax.device_get(state.master["w"]))
        masters.append(w)
np.testing.assert_allclose(masters[0], masters[1], rtol=1e-5, atol=1e-6)
print("ZERO1 EQUIV OK")
"""


def test_zero1_equivalent(subproc):
    out = subproc(ZERO1_EQUIV, devices=8)
    assert "ZERO1 EQUIV OK" in out


SPARSE_ALLGATHER = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.optim import sgd, constant

# TP=2 partial-manual mesh: the configuration whose sparse path used to
# hard-crash the 0.4.x SPMD partitioner through lax.top_k.
mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out = 4, 256, 16
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

masters, bits = [], []
for aggregate, disp in (("dense_psum", "reference"),
                        ("sparse_allgather", "kernel")):
    # the dense baseline keeps reference dispatch: on 0.4.x a kernel
    # output feeding an in-body pmean over an auto-axis-sharded operand
    # trips IsManualSubgroup (ROADMAP open item); the sparse path's
    # compact buffers leave the manual region via out_specs instead,
    # so the kernel compact path does run inside this traced step.
    init_fn, local_step, sync_step = make_dist_steps(
        grad_fn, sgd(), ShardCompressor("topk", 0.05, dispatch=disp),
        constant(0.05), mesh, ("data",), specs, aggregate=aggregate)
    with set_mesh(mesh):
        state = init_fn(params)
        key = jax.random.PRNGKey(1)
        kb, _ = jax.random.split(key)
        x = jax.random.normal(kb, (R, 8, d_in))
        y = jnp.einsum("rbi,io->rbo", x, Wtrue)
        lowered = jax.jit(sync_step).lower(state, (x, y), key).as_text()
        if aggregate == "sparse_allgather":
            # acceptance: the kernel compact path is sort-free end to
            # end; nothing in the traced sparse sync step needs the
            # partitioner support 0.4.x lacks
            assert "top_k" not in lowered, "lax.top_k leaked into sparse sync"
            assert "sort(" not in lowered, "sort leaked into sparse sync"
        ls, ss = jax.jit(local_step), jax.jit(sync_step)
        for t in range(12):
            key, s1, s2 = jax.random.split(key, 3)
            x = jax.random.normal(s1, (R, 8, d_in))
            y = jnp.einsum("rbi,io->rbo", x, Wtrue)
            if (t + 1) % 4 == 0:
                state, loss = ss(state, (x, y), s2)
            else:
                state, loss = ls(state, (x, y), s2)
        masters.append(np.asarray(jax.device_get(state.master["w"])))
        bits.append(float(state.bits))
# identical math, different wire format: same masters, same counted bits
np.testing.assert_allclose(masters[0], masters[1], rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(bits[0], bits[1])
print("SPARSE==DENSE OK", bits[0])
"""


def test_sparse_allgather_kernel_compact(subproc):
    """aggregate="sparse_allgather" runs through the compact kernel path
    on this container (no lax.top_k in the traced step — sort-free even
    inside the 0.4.x partial-manual region) and matches the dense-psum
    aggregation state-for-state and bit-for-bit."""
    out = subproc(SPARSE_ALLGATHER, devices=8)
    assert "SPARSE==DENSE OK" in out


DOWNLINK_PARITY = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.optim import sgd, constant

# legacy-0.4.x TP=2 partial-manual mesh: the downlink must stay
# partition-safe here in BOTH aggregation modes (acceptance criterion)
mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out = 4, 256, 16
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

def run(aggregate, disp, downlink, ddisp):
    dl = None if downlink is None else ShardCompressor(
        "topk", 0.1, dispatch=ddisp)
    init_fn, ls_, ss_ = make_dist_steps(
        grad_fn, sgd(), ShardCompressor("topk", 0.05, dispatch=disp),
        constant(0.05), mesh, ("data",), specs, aggregate=aggregate,
        downlink=dl)
    with set_mesh(mesh):
        state = init_fn(params)
        ls, ss = jax.jit(ls_), jax.jit(ss_)
        key = jax.random.PRNGKey(1)
        for t in range(12):
            key, s1, s2 = jax.random.split(key, 3)
            x = jax.random.normal(s1, (R, 8, d_in))
            y = jnp.einsum("rbi,io->rbo", x, Wtrue)
            if (t + 1) % 4 == 0:
                state, loss = ss(state, (x, y), s2)
            else:
                state, loss = ls(state, (x, y), s2)
    return state

# compressed downlink: the dense-psum and sparse-allgather paths must
# agree on worker state and BOTH directions' counted bits.  The sparse
# leg runs the compact kernels for uplink and downlink alike (the
# buffers leave the manual region via out_specs, 0.4.x-safe).
sd = run("dense_psum", "reference", "topk", "reference")
sp = run("sparse_allgather", "kernel", "topk", "kernel")
np.testing.assert_allclose(np.asarray(sd.master["w"]),
                           np.asarray(sp.master["w"]),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(sd.view["w"]),
                           np.asarray(sp.view["w"]),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(sd.down_memory["w"]),
                           np.asarray(sp.down_memory["w"]),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(float(sd.bits), float(sp.bits))
np.testing.assert_allclose(float(sd.bits_down), float(sp.bits_down))
assert float(sd.bits_down) > 0
# post-sync locals equal the views (workers adopt the compressed
# broadcast, not the true master), and views genuinely lag the master
np.testing.assert_allclose(np.asarray(sd.local["w"]),
                           np.asarray(sd.view["w"]), rtol=0, atol=0)
assert float(jnp.max(jnp.abs(sd.view["w"][0] - sd.master["w"]))) > 0

# identity downlink: trajectories and the uplink ledger are
# bit-identical to the downlink-less run; only the new downlink
# ledger differs (dense broadcast cost vs the same dense cost) —
# i.e. exact backward compat plus honest accounting.
s_none = run("dense_psum", "reference", None, None)
from repro.core import bits as bitlib
dense_leaf_bits = sum(32 * v.size for v in params.values())
assert float(s_none.bits_down) == 3 * R * dense_leaf_bits
print("DOWNLINK PARITY OK", float(sd.bits), float(sd.bits_down))
"""


def test_downlink_dense_sparse_parity(subproc):
    """Compressed downlink: dense-psum and sparse-allgather agree on
    worker states and per-direction counted bits on the legacy 0.4.x
    TP>1 partial-manual mesh (DESIGN.md §5)."""
    out = subproc(DOWNLINK_PARITY, devices=8)
    assert "DOWNLINK PARITY OK" in out


TP_KERNEL_GUARD = r"""
import warnings
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import MODERN, set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.optim import sgd, constant

mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out = 4, 256, 16
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

def run(disp, ddisp=None):
    dl = (None if ddisp is None
          else ShardCompressor("topk", 0.1, dispatch=ddisp))
    init_fn, ls_, ss_ = make_dist_steps(
        grad_fn, sgd(), ShardCompressor("topk", 0.05, dispatch=disp),
        constant(0.05), mesh, ("data",), specs, aggregate="dense_psum",
        downlink=dl)
    with set_mesh(mesh):
        state = init_fn(params)
        ls, ss = jax.jit(ls_), jax.jit(ss_)
        key = jax.random.PRNGKey(1)
        for t in range(8):
            key, s1, s2 = jax.random.split(key, 3)
            x = jax.random.normal(s1, (R, 8, d_in))
            y = jnp.einsum("rbi,io->rbo", x, Wtrue)
            if (t + 1) % 4 == 0:
                state, loss = ss(state, (x, y), s2)
            else:
                state, loss = ls(state, (x, y), s2)
    return state

# ShardCompressor(dispatch="kernel") + dense psum on a TP>1 legacy mesh
# used to hard-crash XLA (IsManualSubgroup, ROADMAP known issue); the
# engine now auto-downgrades the uplink to reference dispatch with a
# one-time warning and identical results.
with warnings.catch_warnings(record=True) as wlog:
    warnings.simplefilter("always")
    s_kernel = run("kernel")
    msgs = [str(w.message) for w in wlog]
if MODERN:
    assert not any("downgrading the uplink" in m for m in msgs), msgs
else:
    assert sum("downgrading the uplink" in m for m in msgs) == 1, msgs
s_ref = run("reference")
np.testing.assert_allclose(np.asarray(s_kernel.master["w"]),
                           np.asarray(s_ref.master["w"]),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(float(s_kernel.bits), float(s_ref.bits))

# the *downlink* channel needs the same guard: its kernel launches also
# trip IsManualSubgroup inside the dense-psum body, even though its
# output never feeds a collective (reproduced before the guard)
with warnings.catch_warnings(record=True) as wlog:
    warnings.simplefilter("always")
    s_dk = run("kernel", ddisp="kernel")
    msgs = [str(w.message) for w in wlog]
if not MODERN:
    assert sum("downgrading the downlink" in m for m in msgs) == 1, msgs
s_dr = run("reference", ddisp="reference")
np.testing.assert_allclose(np.asarray(s_dk.master["w"]),
                           np.asarray(s_dr.master["w"]),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(float(s_dk.bits_down), float(s_dr.bits_down))
print("TP KERNEL GUARD OK")
"""


def test_legacy_tp_kernel_guard(subproc):
    """dispatch="kernel" + dense_psum on a TP>1 0.4.x mesh downgrades
    to reference dispatch with one warning instead of crashing, and
    matches the reference run exactly."""
    out = subproc(TP_KERNEL_GUARD, devices=8)
    assert "TP KERNEL GUARD OK" in out


HETERO_POLICY = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.core import policy as pol
from repro.optim import sgd, constant

# heterogeneous per-leaf policy (DESIGN.md §6) on the legacy TP=2
# partial-manual mesh: Top_k on the matmul, QSGD on the embedding,
# dense on the bias — through BOTH aggregation paths, which must agree
# on states and counted bits (acceptance criterion).
mesh = jax.make_mesh((4, 2), ("data", "model"))
R, d_in, d_out, V = 4, 256, 16, 64
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,)),
          "embed": jnp.zeros((V, d_in))}
specs = {"w": P(None, "model"), "b": P("model"), "embed": P(None, None)}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    def f(pp):
        h = jnp.take(pp["embed"], jnp.arange(8) % V, axis=0)
        return (jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
                + 1e-3 * jnp.sum(h ** 2))
    return jax.value_and_grad(f)(p)

POLICY = "b->identity; embed->qsgd:s=15; .*->topk:k=0.05"

def run(aggregate, disp):
    comp = ShardCompressor.from_spec(POLICY, params, dispatch=disp)
    assert comp.mode == "policy"
    init_fn, ls_, ss_ = make_dist_steps(
        grad_fn, sgd(), comp, constant(0.05), mesh, ("data",), specs,
        aggregate=aggregate)
    with set_mesh(mesh):
        state = init_fn(params)
        ls, ss = jax.jit(ls_), jax.jit(ss_)
        key = jax.random.PRNGKey(1)
        for t in range(12):
            key, s1, s2 = jax.random.split(key, 3)
            x = jax.random.normal(s1, (R, 8, d_in))
            y = jnp.einsum("rbi,io->rbo", x, Wtrue)
            if (t + 1) % 4 == 0:
                state, loss = ss(state, (x, y), s2)
            else:
                state, loss = ls(state, (x, y), s2)
    return state

# the dense leg keeps reference dispatch (0.4.x TP>1 dense-psum kernel
# guard); the sparse leg runs the compact kernels for the Top_k leaf
sd = run("dense_psum", "reference")
sp = run("sparse_allgather", "kernel")
for k in ("w", "b", "embed"):
    np.testing.assert_allclose(np.asarray(sd.master[k]),
                               np.asarray(sp.master[k]),
                               rtol=1e-4, atol=1e-5)
# identical math (the QSGD draw shares the key stream across paths):
# counted bits agree exactly, and the stochastic leaf transmitted
np.testing.assert_allclose(float(sd.bits), float(sp.bits))
assert float(sd.bits) > 0
print("HETERO POLICY PARITY OK", float(sd.bits))
"""


def test_hetero_policy_dense_sparse_parity(subproc):
    """A heterogeneous per-leaf policy (TopK + QSGD + identity) trains
    through both distributed aggregation paths on the legacy 0.4.x
    TP>1 mesh, with dense-psum and sparse-allgather agreeing on states
    and counted bits."""
    out = subproc(HETERO_POLICY, devices=8)
    assert "HETERO POLICY PARITY OK" in out


MULTIPOD = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import make_dist_steps, ShardCompressor
from repro.optim import sgd, constant

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
R = 4  # pod * data
d_in, d_out = 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

init_fn, local_step, sync_step = make_dist_steps(
    grad_fn, sgd(), ShardCompressor("topk", 0.5), constant(0.1),
    mesh, ("pod", "data"), specs)
with set_mesh(mesh):
    state = init_fn(params)
    ls, ss = jax.jit(local_step), jax.jit(sync_step)
    key = jax.random.PRNGKey(1)
    for t in range(160):
        key, s1, s2 = jax.random.split(key, 3)
        x = jax.random.normal(s1, (R, 16, d_in))
        y = jnp.einsum("rbi,io->rbo", x, Wtrue)
        if (t + 1) % 4 == 0:
            state, loss = ss(state, (x, y), s2)
        else:
            state, loss = ls(state, (x, y), s2)
assert float(loss) < 0.1, float(loss)
print("MULTIPOD OK", float(loss))
"""


def test_multipod_axes(subproc):
    out = subproc(MULTIPOD, devices=8)
    assert "MULTIPOD OK" in out


ROUND_PROGRAM_PARITY = r"""
import warnings
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import set_mesh
from repro.core.distributed import (make_dist_round, make_dist_steps,
                                    ShardCompressor)
from repro.optim import sgd, constant

# TP=1 mesh: the fused scan-with-xs round program partitions on 0.4.x
mesh = jax.make_mesh((8, 1), ("data", "model"))
R, d_in, d_out = 8, 16, 8
params = {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))}
specs = {"w": P(None, "model"), "b": P("model")}
params = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda z: isinstance(z, P)))
Wtrue = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))

def grad_fn(p, batch):
    x, y = batch
    f = lambda pp: jnp.mean((x @ pp["w"] + pp["b"] - y) ** 2)
    return jax.value_and_grad(f)(p)

key0 = jax.random.PRNGKey(7)
bs = []
for _ in range(16):
    key0, s = jax.random.split(key0)
    x = jax.random.normal(s, (R, 16, d_in))
    bs.append((x, jnp.einsum("rbi,io->rbo", x, Wtrue)))

H, T = 4, 16
for agg in ("dense_psum", "sparse_allgather"):
    for dl in (None, ShardCompressor("topk", 0.5)):
        comp = ShardCompressor("topk", 0.25)
        common = dict(aggregate=agg, downlink=dl)
        init_fn, ls, ss = make_dist_steps(
            grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
            **common)
        with set_mesh(mesh):
            st = init_fn(params)
            lsj, ssj = jax.jit(ls), jax.jit(ss)
            key = jax.random.PRNGKey(1)
            ref_losses = []
            for t in range(T):
                key, sub = jax.random.split(key)
                step = ssj if (t + 1) % H == 0 else lsj
                st, loss = step(st, bs[t], sub)
                ref_losses.append(float(loss))
            ref = st
        init_fn2, round_fn, fused = make_dist_round(
            grad_fn, sgd(), comp, constant(0.1), mesh, ("data",), specs,
            **common)
        assert fused, "TP=1 legacy mesh must take the fused path"
        with set_mesh(mesh):
            st2 = init_fn2(params)
            key = jax.random.PRNGKey(1)
            losses2 = []
            for r0 in range(0, T, H):
                block = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *bs[r0:r0 + H])
                st2, larr, key = round_fn(st2, block, key)
                losses2.extend(np.asarray(larr).tolist())
        # bit-for-bit: states and both direction ledgers
        np.testing.assert_array_equal(np.asarray(ref.master["w"]),
                                      np.asarray(st2.master["w"]))
        np.testing.assert_array_equal(np.asarray(ref.local["w"]),
                                      np.asarray(st2.local["w"]))
        np.testing.assert_array_equal(np.asarray(ref.memory["w"]),
                                      np.asarray(st2.memory["w"]))
        assert float(ref.bits) == float(st2.bits)
        assert float(ref.bits_down) == float(st2.bits_down)
        assert int(ref.rounds) == int(st2.rounds)
        np.testing.assert_array_equal(np.asarray(ref_losses),
                                      np.asarray(losses2))
        print("ROUND FUSED OK", agg, "downlink" if dl else "nodl")

# TP>1 legacy mesh: make_dist_round must degrade to per-step with a
# one-time warning, keeping identical trajectories
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
params2 = jax.device_put(
    {"w": jnp.zeros((d_in, d_out)), "b": jnp.zeros((d_out,))},
    jax.tree.map(lambda s: NamedSharding(mesh2, s), specs,
                 is_leaf=lambda z: isinstance(z, P)))
with warnings.catch_warnings(record=True) as wlog:
    warnings.simplefilter("always")
    init_fn3, round_fn3, fused3 = make_dist_round(
        grad_fn, sgd(), ShardCompressor("topk", 0.25), constant(0.1),
        mesh2, ("data",), specs)
from repro.compat import MODERN
if not MODERN:
    assert not fused3
    assert any("fused round program" in str(w.message) for w in wlog), \
        [str(w.message) for w in wlog]
    bs2 = [(b[0][:4], b[1][:4]) for b in bs[:4]]
    with set_mesh(mesh2):
        st3 = init_fn3(params2)
        block = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *bs2)
        st3, larr, _ = round_fn3(st3, block, jax.random.PRNGKey(1))
    assert np.all(np.isfinite(np.asarray(larr)))
    assert int(st3.rounds) == 1
print("ROUND FALLBACK OK")
"""


def test_round_program_parity(subproc):
    """DESIGN.md §7: the fused mesh round program (lax.scan over the
    shard_mapped local step + sync at the tail, donated state) is
    bit-for-bit the per-step path on states and both direction ledgers
    for both aggregations, with and without a compressed downlink —
    and degrades to per-step dispatch (one-time warning) on 0.4.x
    TP>1 meshes."""
    out = subproc(ROUND_PROGRAM_PARITY, devices=8, timeout=1500)
    assert out.count("ROUND FUSED OK") == 4
    assert "ROUND FALLBACK OK" in out

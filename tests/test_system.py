"""End-to-end behaviour tests: the paper's headline claims reproduced at
test scale on the convex objective (Section 5.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import (
    Identity,
    QuantizedSparsifier,
    Sign,
    SignSparsifier,
    TopK,
)
from repro.data import mnist_like, worker_batches
from repro.models import softmax
from repro.optim import inverse_time, sgd
from repro.train import RunConfig, train

R, B = 4, 16


@pytest.fixture(scope="module")
def convex_setup():
    x, y = mnist_like(4000, seed=0)
    cfg = softmax.SoftmaxConfig(l2=1.0 / len(x))
    params = softmax.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: softmax.loss_fn(pp, batch, cfg)[0])(p)

    return x, y, cfg, params, grad_fn


def run_with(grad_fn, params, op, H, T, x, y, target=None, seed=0):
    lr = inverse_time(xi=60.0, a=100.0)
    batches = worker_batches(x, y, R, B, T, seed=seed)
    run = RunConfig(total_steps=T, R=R, H=H, log_every=25,
                    target_loss=target, seed=seed)
    state, hist = train(grad_fn, params, sgd(), op, lr, batches, run)
    return state, hist


def test_all_methods_reach_target_loss(convex_setup):
    """Every operator (vanilla / TopK / Sign / QTopK / SignTopK / +local)
    converges to the same loss neighbourhood — the paper's 'compression
    is nearly free in convergence' claim."""
    x, y, cfg, params, grad_fn = convex_setup
    T = 250
    final = {}
    for name, op, H in [
        ("vanilla", Identity(), 1),
        ("topk", TopK(k=0.02), 1),
        ("ef_sign", Sign(), 1),
        ("qtopk", QuantizedSparsifier(k=0.02, s=15), 1),
        ("signtopk", SignSparsifier(k=0.02, m=1), 1),
        ("qsparse_local", QuantizedSparsifier(k=0.02, s=15), 4),
    ]:
        _, hist = run_with(grad_fn, params, op, H, T, x, y)
        final[name] = hist.loss[-1]
    base = final["vanilla"]
    for name, loss in final.items():
        assert loss < base * 1.6 + 0.35, (name, loss, base)


def test_qsparse_saves_bits_vs_baselines(convex_setup):
    """The paper's headline: Qsparse-local-SGD needs far fewer bits to a
    target loss than TopK-SGD and orders less than vanilla SGD."""
    x, y, cfg, params, grad_fn = convex_setup
    T = 400
    target = 1.1
    bits = {}
    for name, op, H in [
        ("vanilla", Identity(), 1),
        ("topk", TopK(k=0.02), 1),
        ("qsparse_local", SignSparsifier(k=0.02, m=1), 4),
    ]:
        _, hist = run_with(grad_fn, params, op, H, T, x, y, target=target)
        assert hist.bits_to_target is not None, (name, hist.loss)
        bits[name] = hist.bits_to_target
    assert bits["topk"] < bits["vanilla"] / 5
    assert bits["qsparse_local"] < bits["topk"] / 2
    assert bits["qsparse_local"] < bits["vanilla"] / 50


def test_error_feedback_necessity(convex_setup):
    """Without memory, aggressive TopK stalls; with the paper's error
    compensation it keeps descending (Section 3.2)."""
    x, y, cfg, params, grad_fn = convex_setup
    T = 250
    _, hist_ef = run_with(grad_fn, params, TopK(k=0.01), 1, T, x, y)

    # plain sparsified SGD: compress the gradient, throw the residual away
    lr = inverse_time(xi=60.0, a=100.0)
    p = params
    opk = TopK(k=0.01)
    losses = []
    for t, batch in enumerate(worker_batches(x, y, R, B, T, seed=0)):
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        gs, ls = [], []
        for r in range(R):
            sub = jax.tree_util.tree_map(lambda v: v[r], batch)
            loss, g = grad_fn(p, sub)
            cg, _ = opk(None, g["x"])
            gs.append({"x": cg, "z": g["z"]})
            ls.append(float(loss))
        gmean = jax.tree_util.tree_map(lambda *v: sum(v) / len(v), *gs)
        eta = float(lr(jnp.asarray(t)))
        p = jax.tree_util.tree_map(lambda a, b: a - eta * b, p, gmean)
        losses.append(np.mean(ls))
    no_ef = float(np.mean(losses[-20:]))
    with_ef = hist_ef.loss[-1]
    assert with_ef < no_ef * 0.9, (with_ef, no_ef)

"""Reusable hypothesis strategies for the repo's property tests.

One vocabulary for every suite that reasons about schedules, [T, R]
sync masks, fleet scenarios, or parameter pytrees — adopted by
test_schedule.py / test_rounds.py / test_scenarios.py instead of each
file hand-rolling its own integer tuples.

Import-safe without hypothesis: the conftest stub turns every strategy
into an inert object and every ``@given`` test into a skip, while the
deterministic grids at the bottom (plain numpy, no hypothesis) keep the
parametrized twin tests running everywhere.
"""

import numpy as np
from hypothesis import strategies as st

from repro.core import scenarios as scn, schedule as sched

# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def schedule_cases(max_T=250, max_R=10, max_H=12, max_seed=10_000):
    """(T, R, H, seed) tuples for schedule/mask generators."""
    return st.tuples(
        st.integers(1, max_T), st.integers(1, max_R),
        st.integers(1, max_H), st.integers(0, max_seed))


def fixed_schedule_cases(max_T=250, max_H=16):
    """(T, H) tuples for the synchronous fixed schedule."""
    return st.tuples(st.integers(1, max_T), st.integers(1, max_H))


# ---------------------------------------------------------------------------
# [T, R] per-worker sync masks
# ---------------------------------------------------------------------------


@st.composite
def sync_masks(draw, max_T=64, max_R=6, require_sync=False):
    """Arbitrary bool[T, R] masks — i.i.d. rows at a drawn density, so
    all-False, partial and dense schedules all appear.  With
    ``require_sync`` at least one True entry is guaranteed."""
    T = draw(st.integers(1, max_T))
    R = draw(st.integers(1, max_R))
    p = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    mask = np.random.RandomState(seed).rand(T, R) < p
    if require_sync and not mask.any():
        t = draw(st.integers(0, T - 1))
        r = draw(st.integers(0, R - 1))
        mask[t, r] = True
    return mask


@st.composite
def scheduled_masks(draw, max_T=48, max_R=6, max_H=8):
    """Masks that came from a real schedule family (fixed broadcast,
    async, or scenario) — the inputs the runtimes actually see."""
    T = draw(st.integers(1, max_T))
    R = draw(st.integers(1, max_R))
    H = draw(st.integers(1, max_H))
    seed = draw(st.integers(0, 9_999))
    family = draw(st.integers(0, 2))
    if family == 0:
        fixed = sched.fixed_schedule(T, H)
        return np.broadcast_to(fixed[:, None], (T, R)).copy()
    if family == 1:
        return sched.async_schedule(T, R, H, seed=seed)
    return draw(scenario_specs()).mask(T, R, H=H)


# ---------------------------------------------------------------------------
# fleet scenarios (core/scenarios.py)
# ---------------------------------------------------------------------------


@st.composite
def scenario_specs(draw, min_participation=0.0):
    """Valid Scenario dataclasses across the whole knob space."""
    hetero = draw(st.booleans())
    lo = draw(st.integers(1, 6))
    hi = draw(st.integers(lo, 12))
    return scn.Scenario(
        participation=draw(st.floats(min_participation, 1.0)),
        dropout_mid_round=draw(st.floats(0.0, 0.5)),
        straggler_frac=draw(st.floats(0.0, 1.0)),
        straggler_stale_rounds=draw(st.integers(1, 6)),
        hetero_H=(lo, hi) if hetero else None,
        seed=draw(st.integers(0, 9_999)),
    )


# ---------------------------------------------------------------------------
# fault-injection specs (core/scenarios.py, DESIGN.md §9)
# ---------------------------------------------------------------------------


@st.composite
def fault_specs(draw, max_delay=6, allow_crash=True, max_seed=9_999):
    """Valid FaultSpec dataclasses across the knob space: delay windows,
    in-flight drops, deterministic crash windows, and random
    crash/recover churn — singly and combined."""
    md = draw(st.integers(0, max_delay))
    crash = ()
    if allow_crash and draw(st.booleans()):
        w = draw(st.integers(0, 5))
        c = draw(st.integers(0, 16))
        crash = ((w, c, c + 1 + draw(st.integers(0, 8))),)
    return scn.FaultSpec(
        max_delay=md,
        min_delay=draw(st.integers(0, md)),
        drop=draw(st.floats(0.0, 0.3)),
        crash=crash,
        crash_rate=draw(st.floats(0.0, 0.08)),
        mean_outage=draw(st.floats(1.0, 8.0)),
        seed=draw(st.integers(0, max_seed)),
    )


@st.composite
def fault_schedules(draw, max_T=32, max_R=5, max_H=6):
    """(mask, tables) pairs: a scheduled [T, R] sync mask plus the
    expanded fault tables that ride it — the exact inputs of
    ``engine.fault_rows`` / ``scenarios.fault_replay``."""
    T = draw(st.integers(2, max_T))
    R = draw(st.integers(1, max_R))
    H = draw(st.integers(1, max_H))
    seed = draw(st.integers(0, 9_999))
    mask = sched.async_schedule(T, R, H, seed=seed)
    spec = draw(fault_specs())
    return mask, spec.tables(T, R)


# ---------------------------------------------------------------------------
# parameter pytrees
# ---------------------------------------------------------------------------


@st.composite
def param_trees(draw, max_leaves=4, max_dim=32):
    """Nested dict pytrees of float32 numpy leaves (1-D / 2-D), the
    shape family the engines train on."""
    n = draw(st.integers(1, max_leaves))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    tree = {}
    for i in range(n):
        shape = tuple(rng.randint(1, max_dim + 1, size=rng.randint(1, 3)))
        leaf = rng.randn(*shape).astype(np.float32)
        if i % 3 == 2:
            tree.setdefault("nested", {})[f"l{i}"] = leaf
        else:
            tree[f"l{i}"] = leaf
    return tree


# ---------------------------------------------------------------------------
# deterministic twins (no hypothesis required — run everywhere)
# ---------------------------------------------------------------------------

#: fixed-seed fault grid covering each fault class alone (delays,
#: delay floors, drops, deterministic crash windows, random churn) plus
#: the kitchen-sink preset; the deterministic counterpart of
#: fault_specs()
FAULT_GRID = [
    scn.FaultSpec(),
    scn.FaultSpec(max_delay=2, seed=1),
    scn.FaultSpec(max_delay=3, min_delay=1, seed=2),
    scn.FaultSpec(max_delay=2, drop=0.25, seed=3),
    scn.FaultSpec(crash=((0, 2, 6), (2, 5, 9))),
    scn.FaultSpec(max_delay=2, crash_rate=0.08, mean_outage=3.0, seed=4),
    scn.FAULT_PRESETS["chaos"],
]

#: fixed-seed scenario grid covering each knob alone plus combinations;
#: the deterministic counterpart of scenario_specs()
SCENARIO_GRID = [
    scn.Scenario(),
    scn.Scenario(participation=0.8, seed=3),
    scn.Scenario(dropout_mid_round=0.2, seed=4),
    scn.Scenario(straggler_frac=0.5, straggler_stale_rounds=2, seed=5),
    scn.Scenario(hetero_H=(1, 6), seed=6),
    scn.PRESETS["flaky_fleet"],
]


#: fixed launch-signature grid for the kernel autotuner
#: (kernels/autotune.py) — small, interpret-friendly shapes spanning
#: each kernel family, both sign modes, single-row and row-blocked
#: geometry; the deterministic counterpart of a tuning sweep.  Tuples
#: are (kernel, rows, row_len, k, sign); qsgd's k field carries s.
TUNE_GRID = [
    ("topk_compress", 1, 512, 16, False),
    ("topk_compress", 1, 2048, 64, True),
    ("topk_compress", 6, 256, 8, False),
    ("topk_compress", 12, 384, 24, True),
    ("topk_compact", 4, 512, 16, False),
    ("topk_compact", 1, 1024, 32, False),
    ("qsgd", 1, 768, 15, False),
    ("qsgd", 5, 256, 7, False),
]


def mask_grid(T=24, R=4, H=3):
    """Deterministic (name, mask) pairs: the fixed broadcast, an async
    schedule, each SCENARIO_GRID mask, and a hand-built partial mask."""
    fixed = sched.fixed_schedule(T, H)
    out = [
        ("fixed", np.broadcast_to(fixed[:, None], (T, R)).copy()),
        ("async", sched.async_schedule(T, R, H, seed=11)),
    ]
    for i, sc in enumerate(SCENARIO_GRID):
        out.append((f"scenario{i}", sc.mask(T, R, H=H)))
    partial = np.broadcast_to(fixed[:, None], (T, R)).copy()
    partial[H - 1, 0] = False        # worker 0 misses the first sync
    partial[:, R - 1] = False        # last worker never syncs
    out.append(("partial", partial))
    return out

"""Pallas kernel correctness sweeps (interpret=True on CPU) against the
pure-jnp oracles in kernels/ref.py — shapes and dtypes swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("rows,n,k", [
    (4, 256, 16), (7, 512, 50), (16, 1024, 10), (1, 128, 4), (9, 384, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sign", [False, True])
def test_topk_compress_matches_ref(rows, n, k, dtype, sign):
    acc = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n)) \
        .astype(dtype)
    sel, mem, cnt = ops.topk_compress(acc, k, sign=sign)
    rsel, rmem, rcnt = ref.topk_compress_ref(acc.astype(jnp.float32), k,
                                             sign=sign)
    np.testing.assert_allclose(np.asarray(sel), np.asarray(rsel),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mem), np.asarray(rmem),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(cnt) == np.asarray(rcnt)).all()


@pytest.mark.parametrize("rows,n,k,kcap", [
    (4, 256, 16, 128), (8, 512, 50, 128), (1, 1024, 10, 128),
    (16, 384, 100, 128), (5, 640, 200, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sign", [False, True])
def test_topk_compact_matches_ref(rows, n, k, kcap, dtype, sign):
    acc = jax.random.normal(jax.random.PRNGKey(rows + n), (rows, n)) \
        .astype(dtype)
    idx, val, mem, cnt = ops.topk_compact(acc, k, kcap, sign=sign)
    ridx, rval, rmem, rcnt = ref.topk_compact_ref(
        acc.astype(jnp.float32), k, kcap, sign=sign)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mem), np.asarray(rmem),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))
    # densify identity: scatter-add(compact) + memory == input (sentinel
    # slots drop out of bounds)
    dense = jax.vmap(lambda o, i, v: o.at[i].add(v, mode="drop"))(
        jnp.zeros((rows, n)), idx, val)
    np.testing.assert_allclose(np.asarray(dense + mem),
                               np.asarray(acc, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rows,n,k", [(8, 512, 32), (3, 300, 7)])
def test_topk_compress_selects_topk(rows, n, k):
    """Bisection selection must contain >= k entries per row and every
    selected magnitude must be >= every rejected magnitude (threshold
    semantics — the exact top-k up to ties)."""
    acc = jax.random.normal(jax.random.PRNGKey(0), (rows, n))
    sel, mem, cnt = ops.topk_compress(acc, k)
    sel, cnt = np.asarray(sel), np.asarray(cnt)
    a = np.abs(np.asarray(acc))
    for r in range(rows):
        picked = sel[r] != 0
        assert cnt[r] >= k
        assert cnt[r] <= k + 4  # 24 bisection rounds: tight selection
        if picked.any() and (~picked).any():
            assert a[r][picked].min() >= a[r][~picked].max() - 1e-6
    # error identity: selected + memory == input
    np.testing.assert_allclose(sel + np.asarray(mem), np.asarray(acc),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,S,H,KV,D,w", [
    (2, 64, 4, 2, 32, -1),
    (1, 100, 4, 4, 16, -1),      # ragged S vs block
    (2, 128, 8, 2, 64, 24),      # sliding window
    (1, 256, 2, 1, 128, 64),     # MQA, bigger head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, KV, D, w, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D)).astype(dtype)
    out = ops.flash_attention(q, k, v, window=w, q_block=32, kv_block=32)
    rout = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), window=w)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rout, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("rows,n,s", [(6, 512, 15), (1, 128, 3), (13, 257, 255)])
def test_qsgd_matches_ref(rows, n, s):
    x = jax.random.normal(jax.random.PRNGKey(7), (rows, n))
    u = jax.random.uniform(jax.random.PRNGKey(8), (rows, n))
    out = ops.qsgd_quantize(x, u, s)
    rout = ref.qsgd_bucketed_ref(x, u, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                               rtol=1e-5, atol=1e-6)


def test_qsgd_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 256))
    outs = []
    for i in range(300):
        u = jax.random.uniform(jax.random.PRNGKey(i), x.shape)
        outs.append(np.asarray(ops.qsgd_quantize(x, u, 4)))
    mean = np.mean(outs, axis=0)
    np.testing.assert_allclose(mean, np.asarray(x), atol=0.25)


def test_model_attention_pallas_path_matches_jnp():
    """cfg.use_pallas routes attn_block_train through the kernel."""
    from repro.configs.base import ModelConfig
    from repro.models import transformer as tr
    kw = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
              d_ff=128, vocab=97, param_dtype="float32", act_dtype="float32",
              q_chunk=8, max_seq_len=64, scan_layers=False, remat=False)
    cfg_j = ModelConfig(**kw)
    cfg_p = ModelConfig(**{**kw, "use_pallas": True})
    params = tr.init_params(jax.random.PRNGKey(0), cfg_j)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 97)
    lj, _ = tr.loss_fn(params, {"tokens": toks}, cfg_j)
    lp, _ = tr.loss_fn(params, {"tokens": toks}, cfg_p)
    np.testing.assert_allclose(float(lj), float(lp), rtol=1e-4)

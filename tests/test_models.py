"""Model-substrate correctness: chunked forms vs sequential oracles,
decode == forward/prefill consistency, sliding-window semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import rwkv6, transformer as tr, zamba2 as zm
from repro.models.layers import chunked_attention


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, window=-1):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd) * hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32)) \
        .reshape(B, S, H, hd)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), S=st.integers(3, 70),
       chunk=st.sampled_from([4, 16, 64]), window=st.sampled_from([-1, 5, 16]))
def test_chunked_attention_matches_naive(seed, S, chunk, window):
    B, H, KV, hd = 2, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = chunked_attention(q, k, v, window=window, q_chunk=chunk)
    ref = naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), S=st.integers(2, 50),
       chunk=st.sampled_from([1, 8, 32]))
def test_wkv6_chunked_vs_ref(seed, S, chunk):
    B, H, N = 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.95 + 0.02
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    ref = rwkv6.wkv6_ref(r, k, v, w, u)
    out = rwkv6.wkv6_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), S=st.integers(2, 50),
       chunk=st.sampled_from([1, 8, 32]))
def test_ssd_chunked_vs_ref(seed, S, chunk):
    Bt, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (Bt, S, N))
    C = jax.random.normal(ks[4], (Bt, S, N))
    D = jnp.ones((H,))
    ref = zm.ssd_ref(x, dt, A, B, C, D)
    out = zm.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# decode == forward
# ---------------------------------------------------------------------------


def _dense_cfg(**kw):
    base = dict(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, param_dtype="float32",
                act_dtype="float32", q_chunk=8, max_seq_len=64)
    base.update(kw)
    return ModelConfig(**base)


def test_dense_decode_matches_forward():
    cfg = _dense_cfg()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    full = tr.forward(params, {"tokens": toks}, cfg)
    logits, cache, _ = tr.prefill(params, {"tokens": toks[:, :8]}, cfg,
                                  max_len=32)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 7]), rtol=3e-4, atol=3e-4)
    for t in range(8, 12):
        lg, cache = tr.decode_step(params, cache, toks[:, t], t, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=3e-4, atol=3e-4)


def test_swa_ring_cache_decode_matches_forward():
    cfg = _dense_cfg(n_layers=4, n_kv_heads=1, swa_pattern=(6, -1))
    params = tr.init_params(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg.vocab)
    full = tr.forward(params, {"tokens": toks}, cfg)
    logits, cache, _ = tr.prefill(params, {"tokens": toks[:, :12]}, cfg,
                                  max_len=32)
    # local-layer ring cache really is window-sized
    assert cache[0].k.shape[1] == 6
    assert cache[1].k.shape[1] == 32
    for t in range(12, 17):
        lg, cache = tr.decode_step(params, cache, toks[:, t], t, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=5e-4, atol=5e-4)


def test_rwkv_decode_matches_prefill():
    cfg = ModelConfig(name="t", family="rwkv6", n_layers=2, d_model=32,
                      d_ff=64, vocab=97, ssm_head_dim=8,
                      param_dtype="float32", act_dtype="float32")
    params = rwkv6.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 13), 0, cfg.vocab)
    _, cache, _ = rwkv6.prefill(params, {"tokens": toks[:, :8]}, cfg)
    for t in range(8, 12):
        ref, _, _ = rwkv6.prefill(params, {"tokens": toks[:, :t + 1]}, cfg)
        lg, cache = rwkv6.decode_step(params, cache, toks[:, t], t, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4)


def test_zamba_decode_matches_prefill():
    cfg = ModelConfig(name="t", family="zamba2", n_layers=5, d_model=32,
                      n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64, vocab=97,
                      ssm_state=8, ssm_head_dim=8, attn_every=2,
                      param_dtype="float32", act_dtype="float32", q_chunk=8)
    params = zm.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 13), 0, cfg.vocab)
    _, cache, _ = zm.prefill(params, {"tokens": toks[:, :8]}, cfg, max_len=32)
    for t in range(8, 12):
        ref, _, _ = zm.prefill(params, {"tokens": toks[:, :t + 1]}, cfg,
                               max_len=32)
        lg, cache = zm.decode_step(params, cache, toks[:, t], t, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   rtol=7e-4, atol=7e-4)


def test_moe_decode_matches_prefill():
    from repro.models import moe
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab=97,
                      n_experts=4, moe_top_k=2, capacity_factor=8.0,
                      param_dtype="float32", act_dtype="float32", q_chunk=8)
    params = moe.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, cache, _ = moe.prefill(params, {"tokens": toks[:, :8]}, cfg, max_len=32)
    ref, _, _ = moe.prefill(params, {"tokens": toks[:, :9]}, cfg, max_len=32)
    lg, cache = moe.decode_step(params, cache, toks[:, 8], 8, cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, 0]),
                               rtol=5e-4, atol=5e-4)


def test_moe_capacity_drops_counted():
    from repro.models import moe
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab=97,
                      n_experts=4, moe_top_k=2, capacity_factor=0.3,
                      param_dtype="float32", act_dtype="float32", q_chunk=8)
    params = moe.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    loss, aux = moe.loss_fn(params, {"tokens": toks}, cfg)
    assert np.isfinite(float(loss))
    assert float(aux["dropped"]) > 0  # capacity 0.3 must drop tokens

"""Paper Figures 4-6 (convex, synchronous): loss & bits for our
composed operators vs the baselines the paper compares against
(vanilla SGD, TopK-SGD, EF-SIGNSGD, EF-QSGD, local SGD), including the
local-iteration sweeps of Figure 5.

Setup mirrors Section 5.2: R=15 workers, b=8, softmax regression with
l2, d=7850, Top_k with k=40 coordinates, lr = c/(lambda (a+t)).
"""

from __future__ import annotations

from benchmarks.common import BenchRow, run_convex
from repro.core import operators as ops

T = 400
TARGET = 1.0
K = 40 / 7850.0   # paper's k=40 coordinates of the weight matrix


def methods():
    return [
        # Figure 4/6 set (H=1)
        ("vanilla_sgd", ops.Identity(), 1),
        ("topk_sgd", ops.TopK(k=K), 1),
        ("ef_signsgd", ops.Sign(), 1),
        ("ef_qsgd_4bit", ops.QSGDQuantizer(s=15), 1),
        ("qtopk_4bit", ops.QuantizedSparsifier(k=K, s=15), 1),
        ("qtopk_2bit", ops.QuantizedSparsifier(k=K, s=3), 1),
        ("signtopk", ops.SignSparsifier(k=K, m=1), 1),
        # Figure 5 local-iteration sweeps
        ("local_sgd_H4", ops.Identity(), 4),
        ("local_sgd_H8", ops.Identity(), 8),
        ("qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4),
        ("qtopk_H8", ops.QuantizedSparsifier(k=K, s=15), 8),
        ("signtopk_H4", ops.SignSparsifier(k=K, m=1), 4),
        ("signtopk_H8", ops.SignSparsifier(k=K, m=1), 8),
    ]


def run():
    rows = []
    results = {}
    for name, op, H in methods():
        r = run_convex(op, H, T, target_loss=TARGET)
        results[name] = r
        btt = r["bits_to_target"]
        rows.append(BenchRow(
            f"convex/{name}", r["us_per_step"],
            f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
            f"bits={r['bits']:.3g};bits_to_target="
            f"{btt if btt is not None else 'n/a'}"))
    # headline savings factors (paper: 10-15x over TopK, ~1000x over vanilla)
    v = results["vanilla_sgd"]["bits_to_target"]
    t = results["topk_sgd"]["bits_to_target"]
    q = results["signtopk_H8"]["bits_to_target"] or \
        results["signtopk_H4"]["bits_to_target"]
    if v and t and q:
        rows.append(BenchRow(
            "convex/savings", 0.0,
            f"vs_topk={t / q:.1f}x;vs_vanilla={v / q:.0f}x"))
    return rows

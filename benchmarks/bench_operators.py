"""Figure-1a/4a companion: per-operator compression quality, wire bits
per round and compression-op throughput on a ResNet-50-sized tensor —
plus the kernel-dispatch path (kernels/dispatch.py) vs the dense
references, the compact wire path (kernel (idx, val) emission vs the
scatter-free reference oracle), and the megabuffer packing of a full
sync round (kernel launches per round + rounds/sec, packed vs
leaf-by-leaf)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import BenchRow
from benchmarks import roofline
from repro.core import operators as ops
from repro.kernels import autotune
from repro.kernels import dispatch as dsp

D = 1_000_000   # ~ one large layer
D_GLOBAL = 1 << 18  # single-kernel-row budget for the global operators


def _launch_keys(op, data, *, compact=False):
    """The autotune ShapeKeys one benchmark entry dispatches."""
    return dsp.launch_plans(op, [data], dsp.DispatchConfig(mode="kernel"),
                            compact=compact)


def _model_bytes(keys):
    """Bytes-moved model (roofline.kernel_bytes_moved) summed over the
    launches of one benchmark entry."""
    total = 0.0
    for key in keys:
        kcap = (dsp.capacity(key.k, key.row_len)
                if key.kernel == "topk_compact" else None)
        total += roofline.kernel_bytes_moved(
            key.kernel, key.rows, key.row_len, key.k, kcap=kcap)
    return total


def _tuned_geometry(keys):
    """derived-string fragment naming the table-resolved block geometry
    of the entry's (first) launch, or the heuristic default."""
    if not keys:
        return f"block_rows={dsp.DEFAULT_BLOCK_ROWS}"
    ent = autotune.lookup(*keys[0][:5])
    if ent is None:
        return f"block_rows={dsp.DEFAULT_BLOCK_ROWS}"
    frag = f"block_rows={ent.block_rows}"
    if ent.chunk:
        frag += f";chunk={ent.chunk}"
    return frag


def _time(fn, *args, n=5):
    """Best-of-N wall time after one warmup (compile) call — the same
    policy as autotune._time_us: the min is robust to the scheduler
    noise that a mean of back-to-back calls folds in, which matters
    for the kernel-vs-reference row pairs the regression gate judges."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (D,))
    rows = []
    table = [
        ("identity", ops.Identity()),
        ("topk_1pct", ops.TopK(k=0.01)),
        ("randk_1pct", ops.RandK(k=0.01)),
        ("qsgd_4bit", ops.QSGDQuantizer(s=15)),
        ("sign", ops.Sign()),
        ("qtopk_1pct_4bit", ops.QuantizedSparsifier(k=0.01, s=15)),
        ("qtopk_scaled", ops.QuantizedSparsifier(k=0.01, s=15, scaled=True)),
        ("signtopk_1pct", ops.SignSparsifier(k=0.01, m=1)),
        ("row_topk", ops.RowTopK(k=0.01, row_len=8192)),
    ]
    for name, op in table:
        fn = jax.jit(lambda k, v, o=op: o(k, v))
        (out, bits), us = _time(fn, jax.random.PRNGKey(1), x)
        rel_err = float(jnp.sum((x - out) ** 2) / jnp.sum(x ** 2))
        ratio = float(bits) / (32 * D)
        rows.append(BenchRow(
            f"op/{name}", us,
            f"rel_err={rel_err:.4f};wire_ratio={ratio:.5f};"
            f"gamma={op.gamma(D):.5f}",
            wire_bits=float(bits), path="reference"))

    # kernel-dispatch path vs reference on the dispatchable operators
    # (interpret mode off-TPU: a correctness/rel-err companion there,
    #  a speed comparison on real TPU backends)
    xg = x[:D_GLOBAL]
    dispatch_table = [
        ("topk_1pct", ops.TopK(k=0.01), xg),
        ("signtopk_1pct_m2", ops.SignSparsifier(k=0.01, m=2), xg),
        ("row_topk", ops.RowTopK(k=0.01, row_len=8192), x),
        ("row_signtopk", ops.RowSignTopK(k=0.01, row_len=8192), x),
        ("qsgd_4bit", ops.QSGDQuantizer(s=15), xg),
    ]
    # autotune the dispatch signatures first (DESIGN.md §10): the kernel
    # rows below resolve their block geometry through the tuning table,
    # exactly as a tuned (--tune) training run would.  The table is
    # persisted per device kind, so re-runs cache-hit and cost nothing.
    tune_keys = []
    for _n, op, data in dispatch_table:
        for key in _launch_keys(op, data):
            if key not in tune_keys:
                tune_keys.append(key)
    autotune.tune(tune_keys)

    for name, op, data in dispatch_table:
        d = int(data.size)
        assert dsp.would_dispatch(op, data.shape,
                                  cfg=dsp.DispatchConfig(mode="kernel")), name
        keys = _launch_keys(op, data)
        mbytes = _model_bytes(keys)
        for mode in ("kernel", "reference"):
            cfg = dsp.DispatchConfig(mode=mode)
            fn = jax.jit(lambda k, v, o=op, c=cfg: dsp.compress_leaf(
                o, k, v, c)[:2])
            (out, bits), us = _time(fn, jax.random.PRNGKey(1), data)
            rel_err = float(jnp.sum((data - out) ** 2) / jnp.sum(data ** 2))
            derived = (f"rel_err={rel_err:.4f};"
                       f"wire_ratio={float(bits) / (32 * d):.5f}")
            if mode == "kernel":
                # %-of-HBM-bound: roofline floor (bytes model / HBM_BW)
                # over measured time — near 100 means memory-bound
                derived += (f";pct_hbm={roofline.pct_hbm_bound(us, mbytes):.1f}"
                            f";{_tuned_geometry(keys)}")
            rows.append(BenchRow(
                f"dispatch/{name}/{mode}", us, derived,
                wire_bits=float(bits), path=mode))

    # compact wire path: the kernel's direct (idx, val) emission vs the
    # scatter-free reference oracle (the sparse_allgather hot loop).
    # Global rows sized so kcap fits the kernel's capacity bound.
    xc = x[: 1 << 17]
    compact_table = [
        ("topk_1pct", ops.TopK(k=0.01), xc),
        ("signtopk_1pct_m2", ops.SignSparsifier(k=0.01, m=2), xc),
        ("row_topk", ops.RowTopK(k=0.01, row_len=8192), x),
        ("row_signtopk", ops.RowSignTopK(k=0.01, row_len=8192), x),
    ]
    for name, op, data in compact_table:
        d = int(data.size)
        ckeys = _launch_keys(op, data, compact=True)
        cbytes = _model_bytes(ckeys)
        for mode in ("kernel", "reference"):
            cfg = dsp.DispatchConfig(mode=mode)
            fn = jax.jit(lambda k, v, o=op, c=cfg: dsp.compact_compress(
                o, k, v, c)[0])
            used = dsp.would_compact(op, data.shape, cfg=cfg)
            assert used == (mode == "kernel"), (name, mode)
            leaf, us = _time(fn, jax.random.PRNGKey(1), data)
            bits = float(leaf.bits)
            derived = f"wire_ratio={bits / (32 * d):.5f};kcap={leaf.kcap}"
            if used:
                derived += f";pct_hbm={roofline.pct_hbm_bound(us, cbytes):.1f}"
            rows.append(BenchRow(
                f"compact/{name}/{mode}", us, derived,
                wire_bits=bits,
                path="kernel" if used else "reference"))

    rows.extend(_bench_packing())
    rows.extend(_bench_channel_round())
    rows.extend(_bench_hetero_policy())
    rows.extend(_bench_runtime())
    return rows


def _bench_runtime():
    """Round-program runtime vs the per-step host loop (DESIGN.md §7):
    the same T-step fixed-H schedule driven per step (one jitted,
    donated dispatch + loss fetch per step) vs as compiled round
    programs (lax.scan over the local phase, sync at the tail, one
    fetch per round).  us_per_call is per *step*; with H=8 the local
    phase dominates, so the row pair gates the host-overhead win the
    runtime exists for.  Identical wire bits — a ledger-parity pin."""
    from repro.core import engine, schedule
    from repro.optim import constant, sgd

    R_, D_, H_, T_ = 4, 4096, 8, 48
    cs = jax.random.normal(jax.random.PRNGKey(30), (R_, D_))
    params = {"w": jnp.zeros(D_)}
    inner = sgd()
    op = ops.TopK(k=0.01)

    def grad_fn(p, data):
        c, noise = data
        g = p["w"] - c + 0.01 * noise
        return 0.5 * jnp.sum((p["w"] - c) ** 2), {"w": g}

    k = jax.random.PRNGKey(31)
    bs = []
    for _ in range(T_):
        k, s = jax.random.split(k)
        bs.append((cs, jax.random.normal(s, (R_, D_))))
    mask = schedule.fixed_schedule(T_, H_)
    step = engine.make_step(grad_fn, inner, op, constant(0.05), R_,
                            global_rounds=True)
    sstep = engine.make_superstep(grad_fn, inner, op, constant(0.05), R_,
                                  global_rounds=True)

    def host_loop():
        st = engine.init(params, inner, R_)
        st, _ = engine.run(st, step, bs, mask, jax.random.PRNGKey(32))
        return st.bits

    def superstep():
        st = engine.init(params, inner, R_)
        st, _ = engine.run_rounds(st, sstep, bs, mask,
                                  jax.random.PRNGKey(32))
        return st.bits

    rows = []
    for name, fn in (("host_loop", host_loop), ("superstep", superstep)):
        bits, us_total = _time(fn, n=5)
        us_step = us_total / T_
        rows.append(BenchRow(
            f"round/steps_per_s/{name}", us_step,
            f"steps_per_s={1e6 / max(us_step, 1e-9):.1f};H={H_};T={T_}",
            wire_bits=float(bits), path=name))

    # overlap vs serialized round driver (DESIGN.md §10): the same
    # schedule driven round-by-round (one dispatch + fetch per round)
    # vs windowed multiround programs (run_rounds_overlap: one scanned
    # program per window of up to 8 rounds, one fetch per window).  The
    # win is largest at H=1 — one round per step, so the serialized
    # driver pays a host round-trip per step — and the ledgers pin
    # bit-for-bit identity between the two drivers.
    for H in (1, 4, 8):
        m = schedule.fixed_schedule(T_, H)

        def serial(m=m):
            st = engine.init(params, inner, R_)
            st, _ = engine.run_rounds(st, sstep, bs, m,
                                      jax.random.PRNGKey(32))
            return st.bits

        def overlap(m=m):
            st = engine.init(params, inner, R_)
            st, _ = engine.run_rounds_overlap(st, sstep, bs, m,
                                              jax.random.PRNGKey(32))
            return st.bits

        pair = {}
        for name, fn in (("serial", serial), ("overlap", overlap)):
            bits, us_total = _time(fn, n=5)
            pair[name] = us_total / T_
            rows.append(BenchRow(
                f"round/overlap/H{H}/{name}", pair[name],
                f"steps_per_s={1e6 / max(pair[name], 1e-9):.1f};"
                f"H={H};T={T_}",
                wire_bits=float(bits), path=name))
        rows[-1].derived += (
            f";speedup={pair['serial'] / max(pair['overlap'], 1e-9):.2f}")
    return rows


def _bench_packing():
    """Megabuffer packing: one multi-leaf sync-round compression, packed
    (one kernel launch per operator-family bucket) vs leaf-by-leaf.
    Launches are counted at trace time; rounds/sec is the steady-state
    call rate of the jitted round."""
    tree = {
        f"layer{i}": jax.random.normal(jax.random.PRNGKey(40 + i),
                                       (128, 2048))
        for i in range(6)
    }
    tree["emb"] = jax.random.normal(jax.random.PRNGKey(50), (64, 4096))
    tree["head"] = jax.random.normal(jax.random.PRNGKey(51), (64, 4096))
    op = ops.TopK(k=0.01)
    d = int(sum(v.size for v in tree.values()))
    rows = []
    for pack in (True, False):
        cfg = dsp.DispatchConfig(mode="kernel", pack=pack)
        fn = jax.jit(lambda k, t, c=cfg: dsp.compress_tree(op, k, t, c))
        dsp.reset_launches()
        fn.lower(jax.random.PRNGKey(1), tree)  # trace -> count launches
        launches = dsp.total_launches()
        (out, bits), us = _time(fn, jax.random.PRNGKey(1), tree)
        rows.append(BenchRow(
            f"pack/sync_round/{'packed' if pack else 'per_leaf'}", us,
            f"launches_per_round={launches};"
            f"rounds_per_s={1e6 / max(us, 1e-9):.2f};"
            f"wire_ratio={float(bits) / (32 * d):.5f}",
            wire_bits=float(bits),
            path="packed" if pack else "per_leaf"))
    return rows


def _bench_hetero_policy():
    """Heterogeneous policy packing (DESIGN.md §6): one sync round of a
    per-leaf policy (Top_k matmuls, QSGD embeddings, dense norms)
    through the channel path, both directions.  Megabuffer packing must
    keep launches/round at one per operator *family* per direction —
    heterogeneous leaves bucket by family, not by leaf."""
    from repro.core import policy as pol
    from repro.core.channel import Channel

    tree = {
        "layers": {f"w{i}": jax.random.normal(jax.random.PRNGKey(80 + i),
                                              (128, 2048))
                   for i in range(6)},
        "embed": jax.random.normal(jax.random.PRNGKey(90), (64, 4096)),
        "head": jax.random.normal(jax.random.PRNGKey(91), (64, 4096)),
        "ln": jax.random.normal(jax.random.PRNGKey(92), (256,)),
    }
    spec = pol.parse(
        "ln->identity;embed|head->qsgd:s=15;.*->topk:k=0.01"
        " >> ln->identity;.*->topk:k=0.05")
    up_tree, down_tree = pol.as_channel_spec(spec).resolve(tree)
    d = int(sum(v.size for v in jax.tree_util.tree_leaves(tree)))
    rows = []
    for pack in (True, False):
        cfg = dsp.DispatchConfig(mode="kernel", pack=pack)
        up = Channel(up_tree, "uplink", cfg)
        down = Channel(down_tree, "downlink", cfg)

        def round_fn(key, acc):
            q, _m, b = up.apply(key, acc)
            q2, _m2, b2 = down.apply(jax.random.fold_in(key, 1), acc)
            return (q, q2), b + b2

        jfn = jax.jit(round_fn)
        dsp.reset_launches()
        jfn.lower(jax.random.PRNGKey(1), tree)
        launches = dict(dsp.LAUNCHES)
        (_, bits), us = _time(jfn, jax.random.PRNGKey(1), tree)
        if pack:
            # the acceptance gate: uplink topk + uplink qsgd +
            # downlink topk = one launch per family per direction
            assert launches["topk_compress"] == 2, launches
            assert launches["qsgd"] == 1, launches
        rows.append(BenchRow(
            f"policy/hetero_round/{'packed' if pack else 'per_leaf'}", us,
            f"launches_per_round={sum(launches.values())};"
            f"rounds_per_s={1e6 / max(us, 1e-9):.2f};"
            f"wire_ratio={float(bits) / (64 * d):.5f}",
            wire_bits=float(bits),
            path="packed" if pack else "per_leaf"))
    return rows


def _bench_channel_round():
    """Channel model (DESIGN.md §5): one sync round's compression cost
    and *total* wire bits, uplink-only (the pre-channel ledger, dense
    broadcast back) vs bidirectional (error-compensated Top_k on the
    downlink master delta too).  Launches are counted at trace time —
    megabuffer packing keeps one kernel launch per operator family per
    direction."""
    from repro.core.channel import Channel

    tree = {
        f"layer{i}": jax.random.normal(jax.random.PRNGKey(60 + i),
                                       (128, 2048))
        for i in range(6)
    }
    delta = {
        k: 0.1 * jax.random.normal(jax.random.PRNGKey(70 + i), v.shape)
        for i, (k, v) in enumerate(tree.items())
    }
    d = int(sum(v.size for v in tree.values()))
    cfg = dsp.DispatchConfig(mode="kernel")
    up = Channel(ops.TopK(k=0.01), "uplink", cfg)
    down = Channel(ops.TopK(k=0.05), "downlink", cfg)
    dense_down = float(32 * d)  # exact broadcast cost per receiver

    def uplink_only(key, acc):
        q, _mem, b = up.apply(key, acc)
        # the dense broadcast back is free compute but real wire cost
        return q, b + dense_down

    def bidirectional(key, acc, dacc):
        q, _mem, b = up.apply(key, acc)
        q2, _mem2, b2 = down.apply(jax.random.fold_in(key, 1), dacc)
        return (q, q2), b + b2

    rows = []
    for name, fn, fnargs in (
            ("uplink_only", uplink_only, (tree,)),
            ("bidirectional", bidirectional, (tree, delta))):
        jfn = jax.jit(fn)
        dsp.reset_launches()
        jfn.lower(jax.random.PRNGKey(1), *fnargs)
        launches = dsp.total_launches()
        (_, bits), us = _time(jfn, jax.random.PRNGKey(1), *fnargs)
        rows.append(BenchRow(
            f"channel/round/{name}", us,
            f"launches_per_round={launches};"
            f"wire_ratio={float(bits) / (64 * d):.5f}",
            wire_bits=float(bits), path="kernel"))
    return rows

"""Figure-1a/4a companion: per-operator compression quality, wire bits
per round and compression-op throughput on a ResNet-50-sized tensor."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import BenchRow
from repro.core import operators as ops

D = 1_000_000  # ~ one large layer


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (D,))
    rows = []
    table = [
        ("identity", ops.Identity()),
        ("topk_1pct", ops.TopK(k=0.01)),
        ("randk_1pct", ops.RandK(k=0.01)),
        ("qsgd_4bit", ops.QSGDQuantizer(s=15)),
        ("sign", ops.Sign()),
        ("qtopk_1pct_4bit", ops.QuantizedSparsifier(k=0.01, s=15)),
        ("qtopk_scaled", ops.QuantizedSparsifier(k=0.01, s=15, scaled=True)),
        ("signtopk_1pct", ops.SignSparsifier(k=0.01, m=1)),
        ("row_topk", ops.RowTopK(k=0.01, row_len=8192)),
    ]
    for name, op in table:
        fn = jax.jit(lambda k, v, o=op: o(k, v))
        out, bits = fn(jax.random.PRNGKey(1), x)
        out.block_until_ready()
        t0 = time.time()
        n = 5
        for i in range(n):
            out, bits = fn(jax.random.PRNGKey(i), x)
        out.block_until_ready()
        us = (time.time() - t0) / n * 1e6
        rel_err = float(jnp.sum((x - out) ** 2) / jnp.sum(x ** 2))
        ratio = float(bits) / (32 * D)
        rows.append(BenchRow(
            f"op/{name}", us,
            f"rel_err={rel_err:.4f};wire_ratio={ratio:.5f};"
            f"gamma={op.gamma(D):.5f}"))
    return rows

"""Roofline report (deliverable g): reads artifacts/dryrun/*.json and
derives the three per-chip roofline terms for every
(arch x shape x mesh):

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` of the SPMD-partitioned module reports
*per-device* flops/bytes (verified against 6ND/chips on gemma3-1b), so
the per-chip terms divide by the per-chip peaks directly — numerically
identical to the global/(chips*peak) formulation.

MODEL_FLOPS uses 6*N*D for training (2*N*D for inference paths) with
N = active params, D = global tokens; the ratio MODEL_FLOPS/HLO_FLOPs
(global) exposes remat/attention/dispatch overheads.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import BenchRow

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART_DIR = "artifacts/dryrun"


def kernel_bytes_moved(kernel: str, rows: int, row_len: int, k: int,
                       kcap: int | None = None,
                       dtype_bytes: int = 4) -> float:
    """Minimum HBM traffic (bytes) of one fused compression-kernel
    launch — the bytes-moved model behind the %-of-HBM-bound column on
    the ``dispatch/*`` benchmark rows.

    All three kernels stream the [rows, row_len] accumulator once and
    write their outputs once; none re-reads its inputs (the per-row
    bisection runs on VMEM-resident blocks):

    * ``topk_compress``: read acc, write (selected, new_memory) dense
      planes + a per-row count → 3 planes + 4·rows;
    * ``topk_compact``: read acc, write new_memory dense + the compact
      (idx, val) survivor buffers (kcap slots/row, idx int32 + val f32)
      + a per-row count → 2 planes + 2·kcap·rows·4 + 4·rows;
    * ``qsgd``: read (x, u), write quantized → 3 planes.

    The HBM-bound floor of a launch is then bytes / HBM_BW; the
    benchmark reports floor/measured as ``pct_hbm`` — near 100% means
    the kernel is memory-bound at the roofline, small values mean
    compute (or, in interpret mode, the emulator) dominates.
    """
    plane = float(rows) * row_len * dtype_bytes
    if kernel == "topk_compress":
        return 3 * plane + 4 * rows
    if kernel == "topk_compact":
        if kcap is None:
            raise ValueError("topk_compact bytes model needs kcap")
        return 2 * plane + rows * kcap * (4 + dtype_bytes) + 4 * rows
    if kernel == "qsgd":
        return 3 * plane
    raise ValueError(f"unknown kernel {kernel!r}")


def hbm_bound_us(bytes_moved: float) -> float:
    """The roofline floor: time (µs) to move ``bytes_moved`` at HBM_BW."""
    return bytes_moved / HBM_BW * 1e6


def pct_hbm_bound(measured_us: float, bytes_moved: float) -> float:
    """measured time as a fraction of the HBM-bound floor, in percent
    (capped nowhere: >100 would mean faster than the model, i.e. the
    model under-counts)."""
    return 100.0 * hbm_bound_us(bytes_moved) / max(measured_us, 1e-9)


def model_flops(rec: dict) -> float:
    n = rec.get("active_params", rec.get("params", 0))
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n * tokens
    return 2.0 * n * rec["global_batch"]  # decode: one token per sequence


def analyze(rec: dict, step_name: str | None = None) -> dict | None:
    if rec.get("status") != "ok":
        return None
    steps = rec["steps"]
    name = step_name or ("sync_step" if "sync_step" in steps
                         else next(iter(steps)))
    st = steps[name]
    chips = rec["n_devices"]
    t_comp = st["flops"] / PEAK_FLOPS
    t_mem = st["bytes_accessed"] / HBM_BW
    t_coll = st["collectives"]["total"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    ratio = mf / (st["flops"] * chips) if st["flops"] > 0 else float("nan")
    hints = {
        "compute": "reduce recompute (remat policy) / increase arithmetic "
                   "intensity per chip",
        "memory": "fuse/stream weight reads; shard more state (ZeRO); "
                  "larger per-chip batch amortizes weight traffic",
        "collective": "overlap or shrink collectives: compressed/sparse "
                      "aggregation, fewer all-gathers (act resharding), "
                      "bigger H (fewer syncs)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": name,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": ratio,
        "temp_gib": st["memory"]["temp_bytes"] / 2 ** 30,
        "arg_gib": st["memory"]["argument_bytes"] / 2 ** 30,
        "hint": hints[dom],
    }


def load_records(art_dir: str = ART_DIR, tag: str = "") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        base = os.path.basename(fn)
        has_tag = "__" in base.replace(".json", "").split("__", 3)[-1] \
            if base.count("__") >= 3 else False
        if tag:
            if not base.endswith(f"__{tag}.json"):
                continue
        elif base.count("__") >= 3:
            continue  # tagged experiment artifacts are not baselines
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | step | compute s | memory s | "
           "collective s | dominant | useful | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['temp_gib']:.2f} |")
    return "\n".join(lines)


def run():
    recs = load_records()
    rows = []
    out = []
    for rec in recs:
        a = analyze(rec)
        if a is None:
            continue
        rows.append(a)
        tot = a["t_compute_s"] + a["t_memory_s"] + a["t_collective_s"]
        out.append(BenchRow(
            f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
            tot * 1e6,
            f"dom={a['dominant']};compute={a['t_compute_s']:.3e};"
            f"memory={a['t_memory_s']:.3e};"
            f"collective={a['t_collective_s']:.3e};"
            f"useful={a['useful_ratio']:.2f}"))
    if rows:
        os.makedirs("artifacts", exist_ok=True)
        with open("artifacts/roofline.md", "w") as f:
            f.write(markdown_table(rows) + "\n")
    return out

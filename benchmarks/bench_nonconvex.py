"""Paper Figures 1-3 (non-convex, synchronous): ResNet (the paper's
model family, CIFAR-scale variant of the same code that expresses
ResNet-50) trained with momentum-SGD local iterations, comparing
vanilla / TopK / EF-Sign / QTopK / SignTopK / Qsparse-local on bits.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import BenchRow
from repro.core import operators as ops
from repro.data import make_image_data, worker_batches
from repro.models import resnet
from repro.optim import momentum_sgd, piecewise_decay
from repro.train import RunConfig, train

R, B, T = 4, 16, 150
TARGET = 1.2


def run():
    cfg = resnet.resnet8_config()
    x, y = make_image_data(4000, hw=16, seed=0)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: resnet.loss_fn(pp, batch, cfg)[0])(p)

    lr = piecewise_decay(0.05, [100, 130])
    rows = []
    results = {}
    for name, op, H in [
        ("vanilla_sgd", ops.Identity(), 1),
        ("topk_sgd", ops.TopK(k=0.01), 1),
        ("ef_signsgd", ops.Sign(), 1),
        ("qtopk_4bit", ops.QuantizedSparsifier(k=0.01, s=15), 1),
        ("signtopk", ops.SignSparsifier(k=0.01, m=1), 1),
        ("signtopk_H4", ops.SignSparsifier(k=0.01, m=1), 4),
        ("signtopk_H8", ops.SignSparsifier(k=0.01, m=1), 8),
    ]:
        batches = worker_batches(x, y, R, B, T, seed=1,
                                 feature_key="images")
        run_cfg = RunConfig(total_steps=T, R=R, H=H, log_every=25,
                            target_loss=TARGET)
        t0 = time.time()
        state, hist = train(grad_fn, params, momentum_sgd(0.9), op, lr,
                            batches, run_cfg)
        us = (time.time() - t0) / T * 1e6
        results[name] = hist
        btt = hist.bits_to_target
        rows.append(BenchRow(
            f"nonconvex/{name}", us,
            f"loss={hist.loss[-1]:.3f};bits={hist.bits[-1]:.3g};"
            f"bits_to_target={btt if btt is not None else 'n/a'}"))
    v = results["vanilla_sgd"].bits_to_target
    t = results["topk_sgd"].bits_to_target
    q = (results["signtopk_H8"].bits_to_target
         or results["signtopk_H4"].bits_to_target)
    if v and t and q:
        rows.append(BenchRow("nonconvex/savings", 0.0,
                             f"vs_topk={t / q:.1f}x;vs_vanilla={v / q:.0f}x"))
    return rows

"""Paper Figure 8 / Appendix D: scaled (Lemma 2) vs unscaled (Lemma 1)
QTop_k composed operators, at several local-iteration counts."""

from __future__ import annotations

from benchmarks.common import BenchRow, run_convex
from repro.core import operators as ops

T = 300
K = 40 / 7850.0


def run():
    rows = []
    for H in (1, 4, 8):
        for scaled in (False, True):
            op = ops.QuantizedSparsifier(k=K, s=15, scaled=scaled)
            r = run_convex(op, H, T)
            tag = "scaled" if scaled else "unscaled"
            rows.append(BenchRow(
                f"scaledvs/qtopk_{tag}_H{H}", r["us_per_step"],
                f"loss={r['final_loss']:.4f};err={r['eval_error']:.3f};"
                f"bits={r['bits']:.3g}"))
    return rows

"""Serving-path benchmarks (DESIGN.md §11): compressed-weight GEMM
micro-rows and end-to-end engine throughput.

Two row families:

* ``serve/gemm/<kind>/b<B>`` — one compressed matmul (sparse (idx,val)
  or QSGD dequant-fused) on a d_model-sized layer at activation batch
  B in {1, 8, 32}, against the same shape through the dense ``x @ W``
  path.  derived carries the dense-path time so the compression
  overhead is visible in one row.
* ``serve/engine/<mode>/b<B>`` — the ServeEngine driving a burst of
  requests through the smoke transformer at max_batch B, compressed vs
  dense weights.  us_per_call is one engine step; derived carries the
  aggregate tokens/s, requests/s and mean TTFT — the serving numbers
  the paper-scale deployment cares about.
* ``serve/kv/<layout>/b<B>`` — the same engine burst across KV-cache
  layouts (DESIGN.md §12): per-slot contiguous, shared page pool, and
  int8-quantized pages.  derived carries tok/s + TTFT plus
  ``max_admissible`` — how many concurrent 16-token requests the
  *contiguous layout's* KV HBM budget admits under each layout
  (contiguous reserves max_len per slot; paged holds
  ceil(tokens/page_size) pages; int8 pages pack ~4x more tokens per
  byte), the capacity win paged admission buys at fixed memory.

Every row lands in ``BENCH_serve.json`` and is gated by
``check_regression.py`` like the other suites.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow
from repro.configs import get_config
from repro.configs.policies import get_policy_preset
from repro.kernels import dispatch as dsp
from repro.models import get_model
from repro.serve import ServeEngine, compressed as sc

ARCH = "yi-6b"           # dense-family smoke config (d=256, L=2)
GEMM_BATCHES = (1, 8, 32)
ENGINE_BATCHES = (1, 8, 32)
NEW_TOKENS = 8
PROMPT_PAD = 8


def _time(fn, *args, n=5):
    """Best-of-N wall time after one warmup (compile) call."""
    fn(*args)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _gemm_rows():
    """Compressed matmul vs dense matmul on one transformer layer."""
    rng = np.random.RandomState(0)
    d_in, d_out = 256, 688        # the smoke swiglu up-projection shape
    w = jnp.asarray(rng.randn(d_in, d_out), jnp.float32)
    cfgd = dsp.DispatchConfig(mode="auto")
    path = "kernel" if cfgd.kernels_enabled() else "reference"

    sp = sc.compress_tree({"w": w}, ".*->topk:k=0.05")["w"]
    qd = sc.compress_tree({"w": w}, ".*->qsgd:s=15")["w"]
    assert isinstance(sp, sc.CompressedTensor) and sp.kind == "sparse"
    assert isinstance(qd, sc.CompressedTensor) and qd.kind == "quant"

    rows = []
    for b in GEMM_BATCHES:
        x = jnp.asarray(rng.randn(b, d_in), jnp.float32)
        dense_us = _time(jax.jit(lambda x: x @ w), x)
        for kind, ct in (("sparse", sp), ("qdq", qd)):
            us = _time(jax.jit(ct.matmul), x)
            rows.append(BenchRow(
                name=f"serve/gemm/{kind}/b{b}",
                us_per_call=us,
                derived=(f"dense_us={dense_us:.1f};"
                         f"ratio={us / max(dense_us, 1e-9):.2f};"
                         f"bytes={ct.compressed_bytes}"),
                path=path,
            ))
    return rows


def _engine_row(params, cfg, mode, b):
    eng = ServeEngine(params, cfg, max_batch=b,
                      max_len=PROMPT_PAD + NEW_TOKENS + 4,
                      prompt_pad=PROMPT_PAD)
    rng = np.random.RandomState(0)
    for _ in range(b):
        plen = int(rng.randint(max(2, PROMPT_PAD // 2), PROMPT_PAD + 1))
        eng.submit(rng.randint(0, cfg.vocab, plen).tolist(),
                   max_new_tokens=NEW_TOKENS)
    res = eng.run()
    mets = list(res["metrics"].values())
    ttft_ms = 1e3 * float(np.mean([m.ttft_s for m in mets]))
    return BenchRow(
        name=f"serve/engine/{mode}/b{b}",
        us_per_call=res["wall_s"] / max(res["steps"], 1) * 1e6,
        derived=(f"tok_s={res['tokens_per_s']:.1f};"
                 f"req_s={res['requests_per_s']:.2f};"
                 f"ttft_ms={ttft_ms:.1f};steps={res['steps']}"),
        path=mode,
    )


def _engine_rows():
    cfg = get_config(ARCH, smoke=True)
    model = get_model(cfg)
    dense = model.init_params(jax.random.PRNGKey(0), cfg)
    comp = sc.compress_tree(dense, get_policy_preset("arch", ARCH))
    sc.reset_stats()
    rows = []
    for b in ENGINE_BATCHES:
        rows.append(_engine_row(comp, cfg, "compressed", b))
        rows.append(_engine_row(dense, cfg, "dense", b))
    assert sc.STATS["densify"] == 0, (
        f"serving bench densified {sc.STATS['densify']} leaves")
    return rows


KV_BATCH = 8
KV_MAX_LEN = 40
KV_PAGE_SIZE = 8
KV_REQ_TOKENS = PROMPT_PAD + NEW_TOKENS   # peak tokens per request


def _kv_admissible(cfg, layout):
    """Concurrent KV_REQ_TOKENS-token requests admissible at the
    contiguous layout's HBM budget (KV_BATCH slots x KV_MAX_LEN)."""
    tok_f32 = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim * 4
    budget = KV_BATCH * KV_MAX_LEN * tok_f32
    if layout == "contig":
        per_req = KV_MAX_LEN * tok_f32            # a whole slot
    else:
        pages = -(-KV_REQ_TOKENS // KV_PAGE_SIZE)
        tok = (cfg.n_layers * 2 * (cfg.n_kv_heads * cfg.head_dim + 4)
               if layout == "paged_q8" else tok_f32)
        per_req = pages * KV_PAGE_SIZE * tok
    return budget // per_req


def _kv_row(params, cfg, layout):
    eng = ServeEngine(params, cfg, max_batch=KV_BATCH, max_len=KV_MAX_LEN,
                      prompt_pad=PROMPT_PAD, paged=layout != "contig",
                      page_size=KV_PAGE_SIZE,
                      kv_quant=layout == "paged_q8")
    rng = np.random.RandomState(0)
    for _ in range(KV_BATCH):
        plen = int(rng.randint(max(2, PROMPT_PAD // 2), PROMPT_PAD + 1))
        eng.submit(rng.randint(0, cfg.vocab, plen).tolist(),
                   max_new_tokens=NEW_TOKENS)
    res = eng.run()
    mets = list(res["metrics"].values())
    ttft_ms = 1e3 * float(np.mean([m.ttft_s for m in mets]))
    extra = ""
    if layout != "contig":
        pool = res["pool"]
        extra = (f";peak_pages={pool['peak_pages_used']}"
                 f"/{pool['n_pages']}")
    return BenchRow(
        name=f"serve/kv/{layout}/b{KV_BATCH}",
        us_per_call=res["wall_s"] / max(res["steps"], 1) * 1e6,
        derived=(f"tok_s={res['tokens_per_s']:.1f};"
                 f"ttft_ms={ttft_ms:.1f};steps={res['steps']};"
                 f"max_admissible={_kv_admissible(cfg, layout)}"
                 f"{extra}"),
        path=layout,
    )


def _kv_rows():
    cfg = get_config(ARCH, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    return [_kv_row(params, cfg, layout)
            for layout in ("contig", "paged", "paged_q8")]


def run() -> list:
    return _gemm_rows() + _engine_rows() + _kv_rows()

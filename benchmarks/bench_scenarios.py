"""Fleet scenario lab (DESIGN.md §8): convergence vs participation and
throughput vs fleet size.

Two row families:

* ``scenario/convergence/p<pct>`` — the convex softmax problem under a
  participation-p fleet with support_weighted aggregation: final loss /
  eval error / uplink bits as participation drops 1.0 -> 0.5.  The
  p100 row runs the lossless scenario and doubles as the bit-for-bit
  anchor (it is the plain synchronous schedule).
* ``scenario/steps_per_s/R<R>`` — synthetic-quadratic engine throughput
  as the worker axis grows 8 -> 1024 (the vmapped fleet;
  ``engine.shard_worker_axis`` spreads the same axis over a mesh when
  more than one device is present).

Both families land in ``BENCH_scenarios.json`` and are gated by
``check_regression.py`` like every other suite.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, convex_problem
from repro.core import engine, operators as ops, scenarios as scn
from repro.data import worker_batches
from repro.optim import constant, inverse_time, sgd
from repro.train import RunConfig, train

T_CONV = 300
K = 40 / 7850.0


def _convergence(participation, seed=0, R=15, b=8, H=4):
    x, y, cfg, params, grad_fn, eval_fn = convex_problem()
    sc = scn.Scenario(participation=participation, seed=seed + 1)
    run_cfg = RunConfig(total_steps=T_CONV, R=R, H=H, log_every=25,
                        seed=seed, eval_every=0,
                        scenario=sc, aggregate="support_weighted")
    batches = worker_batches(x, y, R, b, T_CONV, seed=seed)
    op = ops.QuantizedSparsifier(k=K, s=15)
    t0 = time.time()
    state, hist = train(grad_fn, params, sgd(),
                        op, inverse_time(xi=60.0, a=100.0), batches,
                        run_cfg)
    wall = time.time() - t0
    metrics = eval_fn(state.master)
    mask = sc.mask(T_CONV, R, H=H)
    return {
        "final_loss": hist.loss[-1],
        "eval_error": float(metrics["error"]),
        "bits": hist.bits[-1],
        "p_hat": scn.participation_of(mask),
        "us_per_step": wall / T_CONV * 1e6,
    }


def _steps_per_s(R, D=2048, T=16, warmup=4):
    sc = scn.PRESETS["flaky_fleet"]
    mask = sc.mask(T + warmup, R, H=4)

    def grad_fn(p, data):
        err = p["w"] - data
        return 0.5 * jnp.sum(err ** 2), {"w": err}

    inner = sgd()
    state = engine.init({"w": jnp.zeros(D)}, inner, R)
    if len(jax.devices()) > 1 and R % len(jax.devices()) == 0:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        state = engine.shard_worker_axis(state, mesh)
    step = engine.make_step(grad_fn, inner, ops.TopK(k=0.05), constant(0.05),
                            R, global_rounds=True,
                            aggregate="support_weighted")
    bs = [jnp.ones((R, D)) for _ in range(T + warmup)]
    key = jax.random.PRNGKey(0)
    state, _ = engine.run(state, step, bs[:warmup], mask[:warmup], key)
    jax.block_until_ready(state.master["w"])
    t0 = time.time()
    state, _ = engine.run(state, step, bs[warmup:], mask[warmup:], key)
    jax.block_until_ready(state.master["w"])
    wall = time.time() - t0
    return {"us_per_step": wall / T * 1e6,
            "steps_per_s": T / wall,
            "bits": float(state.bits)}


def run():
    rows = []
    for pct in (100, 80, 50):
        r = _convergence(pct / 100.0)
        rows.append(BenchRow(
            f"scenario/convergence/p{pct}", r["us_per_step"],
            f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
            f"bits={r['bits']:.3g};p_hat={r['p_hat']:.2f}",
            wire_bits=None))
    for R in (8, 64, 256, 1024):
        r = _steps_per_s(R)
        # exact-k topk on a deterministic mask: the uplink ledger is
        # machine-independent — gate it as wire_bits
        rows.append(BenchRow(
            f"scenario/steps_per_s/R{R}", r["us_per_step"],
            f"steps_per_s={r['steps_per_s']:.1f};bits={r['bits']:.3g}",
            wire_bits=r["bits"]))
    return rows

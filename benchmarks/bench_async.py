"""Paper Figure 7 (asynchronous convex) under *executed* staleness.

Earlier revisions modelled staleness: ``asynchronous=True`` draws
per-worker sync times U[1, H], but every payload still landed the step
it was computed.  This suite now drives the staleness-first fault
runtime (DESIGN.md §9) — a payload compressed at step t rides the
in-flight queue and is applied at t+τ, with the uplink error memory
updated at compute time — so the async rows measure the algorithm the
convergence theory actually bounds.

Three row families:

* paper rows — Figure 7 operators on the Algorithm-2 schedule, now
  with executed delays (τ ~ U[0, 2]), plus the synchronous anchors;
* ``stale_tau*`` — convergence vs max staleness: TopK/H=4 with
  τ ~ U[0, τmax] for τmax ∈ {0, 2, 4, 8} (τmax = 0 routes through the
  fault runtime with trivial tables — same queue machinery, zero
  delay), plus a staleness-damped (1/(1+τ)) variant at the worst τmax;
* ``qdepth*`` — steps/s vs queue depth: wall-clock cost of carrying a
  depth-D in-flight buffer per worker (depth = τmax + 1).

Plus the staggered round-robin mask only the generalized per-worker
sync mask can express (worker r syncs when (t+1) % H == r % H).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BenchRow, convex_problem, run_convex
from repro.core import engine, operators as ops
from repro.data import worker_batches
from repro.optim import inverse_time, sgd

T = 400
T_DEPTH = 200          # throughput rows: convergence is not the metric
K = 40 / 7850.0
TARGET = 1.0
STALE_TAUS = (0, 2, 4, 8)
QUEUE_DEPTHS = (1, 2, 4, 8)


def _delays(tau_max: int, seed: int = 1) -> str:
    """FaultSpec string: pure delay injection, τ ~ U[0, τmax]."""
    if tau_max == 0:
        return "preset:none"
    return f"max_delay={tau_max},seed={seed}"


def _derived(r) -> str:
    btt = r["bits_to_target"]
    return (f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
            f"bits={r['bits']:.3g};bits_to_target="
            f"{btt if btt is not None else 'n/a'}")


def _staggered_round_robin(op, H, T, R=15, b=8, seed=0):
    x, y, cfg, params, grad_fn, eval_fn = convex_problem()
    mask = np.zeros((T, R), dtype=bool)
    for r in range(R):
        mask[(np.arange(1, T + 1) % H) == (r % H), r] = True
    mask[T - 1, :] = True
    state = engine.init(params, sgd(), R)
    step = jax.jit(engine.make_step(
        grad_fn, sgd(), op, inverse_time(xi=60.0, a=100.0), R))
    t0 = time.time()
    state, losses = engine.run(
        state, step, worker_batches(x, y, R, b, T, seed=seed), mask,
        jax.random.PRNGKey(seed))
    wall = time.time() - t0
    metrics = eval_fn(state.master)
    return {
        "final_loss": float(np.mean(losses[-20:])),
        "eval_error": float(metrics["error"]),
        "bits": float(state.bits),
        "bits_to_target": None,
        "us_per_step": wall / T * 1e6,
    }


def run():
    rows = []
    # Figure 7 operators: async rows carry executed delays (τ ≤ 2).
    for name, op, H, asy, faults in [
        ("sync_vanilla", ops.Identity(), 1, False, None),
        ("async_topk_H4", ops.TopK(k=K), 4, True, _delays(2)),
        ("async_signtopk_H4", ops.SignSparsifier(k=K, m=1), 4, True,
         _delays(2)),
        ("async_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, True,
         _delays(2)),
        ("async_qtopk_H8", ops.QuantizedSparsifier(k=K, s=15), 8, True,
         _delays(2)),
        ("sync_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, False,
         None),
    ]:
        r = run_convex(op, H, T, asynchronous=asy, target_loss=TARGET,
                       faults=faults)
        rows.append(BenchRow(f"async/{name}", r["us_per_step"], _derived(r)))
    # Convergence vs max staleness (executed τ ~ U[0, τmax]).
    for tau in STALE_TAUS:
        r = run_convex(ops.TopK(k=K), 4, T, asynchronous=True,
                       target_loss=TARGET, faults=_delays(tau))
        rows.append(BenchRow(
            f"async/stale_tau{tau}", r["us_per_step"],
            f"tau_max={tau};" + _derived(r)))
    r = run_convex(ops.TopK(k=K), 4, T, asynchronous=True,
                   target_loss=TARGET, faults=_delays(STALE_TAUS[-1]),
                   staleness_weight="damped")
    rows.append(BenchRow(
        f"async/stale_tau{STALE_TAUS[-1]}_damped", r["us_per_step"],
        f"tau_max={STALE_TAUS[-1]};weight=damped;" + _derived(r)))
    # Steps/s vs queue depth (depth = τmax + 1; throughput rows).
    for depth in QUEUE_DEPTHS:
        r = run_convex(ops.TopK(k=K), 4, T_DEPTH, asynchronous=True,
                       faults=_delays(depth - 1, seed=2))
        rows.append(BenchRow(
            f"async/qdepth{depth}", r["us_per_step"],
            f"depth={depth};loss={r['final_loss']:.3f};"
            f"bits={r['bits']:.3g};bits_to_target=n/a"))
    r = _staggered_round_robin(ops.TopK(k=K), 4, T)
    rows.append(BenchRow(
        "async/staggered_rr_topk_H4", r["us_per_step"],
        f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
        f"bits={r['bits']:.3g};bits_to_target=n/a"))
    return rows

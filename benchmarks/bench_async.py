"""Paper Figure 7 (asynchronous convex): Algorithm 2 with per-worker
sync times drawn U[1, H], vs the synchronous counterparts."""

from __future__ import annotations

from benchmarks.common import BenchRow, run_convex
from repro.core import operators as ops

T = 400
K = 40 / 7850.0
TARGET = 1.0


def run():
    rows = []
    for name, op, H, asy in [
        ("sync_vanilla", ops.Identity(), 1, False),
        ("async_topk_H4", ops.TopK(k=K), 4, True),
        ("async_signtopk_H4", ops.SignSparsifier(k=K, m=1), 4, True),
        ("async_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, True),
        ("async_qtopk_H8", ops.QuantizedSparsifier(k=K, s=15), 8, True),
        ("sync_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, False),
    ]:
        r = run_convex(op, H, T, asynchronous=asy, target_loss=TARGET)
        btt = r["bits_to_target"]
        rows.append(BenchRow(
            f"async/{name}", r["us_per_step"],
            f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
            f"bits={r['bits']:.3g};bits_to_target="
            f"{btt if btt is not None else 'n/a'}"))
    return rows

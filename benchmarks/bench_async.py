"""Paper Figure 7 (asynchronous convex): Algorithm 2 with per-worker
sync times drawn U[1, H], vs the synchronous counterparts — all driven
through the unified engine (core/engine.py), plus a staggered
round-robin mask that only the generalized per-worker sync mask can
express (worker r syncs when (t+1) % H == r % H: the master is touched
every step, each worker every H steps)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BenchRow, convex_problem, run_convex
from repro.core import engine, operators as ops
from repro.data import worker_batches
from repro.optim import inverse_time, sgd

T = 400
K = 40 / 7850.0
TARGET = 1.0


def _staggered_round_robin(op, H, T, R=15, b=8, seed=0):
    x, y, cfg, params, grad_fn, eval_fn = convex_problem()
    mask = np.zeros((T, R), dtype=bool)
    for r in range(R):
        mask[(np.arange(1, T + 1) % H) == (r % H), r] = True
    mask[T - 1, :] = True
    state = engine.init(params, sgd(), R)
    step = jax.jit(engine.make_step(
        grad_fn, sgd(), op, inverse_time(xi=60.0, a=100.0), R))
    t0 = time.time()
    state, losses = engine.run(
        state, step, worker_batches(x, y, R, b, T, seed=seed), mask,
        jax.random.PRNGKey(seed))
    wall = time.time() - t0
    metrics = eval_fn(state.master)
    return {
        "final_loss": float(np.mean(losses[-20:])),
        "eval_error": float(metrics["error"]),
        "bits": float(state.bits),
        "bits_to_target": None,
        "us_per_step": wall / T * 1e6,
    }


def run():
    rows = []
    for name, op, H, asy in [
        ("sync_vanilla", ops.Identity(), 1, False),
        ("async_topk_H4", ops.TopK(k=K), 4, True),
        ("async_signtopk_H4", ops.SignSparsifier(k=K, m=1), 4, True),
        ("async_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, True),
        ("async_qtopk_H8", ops.QuantizedSparsifier(k=K, s=15), 8, True),
        ("sync_qtopk_H4", ops.QuantizedSparsifier(k=K, s=15), 4, False),
    ]:
        r = run_convex(op, H, T, asynchronous=asy, target_loss=TARGET)
        btt = r["bits_to_target"]
        rows.append(BenchRow(
            f"async/{name}", r["us_per_step"],
            f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
            f"bits={r['bits']:.3g};bits_to_target="
            f"{btt if btt is not None else 'n/a'}"))
    r = _staggered_round_robin(ops.TopK(k=K), 4, T)
    rows.append(BenchRow(
        "async/staggered_rr_topk_H4", r["us_per_step"],
        f"loss={r['final_loss']:.3f};err={r['eval_error']:.3f};"
        f"bits={r['bits']:.3g};bits_to_target=n/a"))
    return rows

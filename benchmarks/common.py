"""Shared benchmark scaffolding.

Each bench_* module exposes ``run() -> list[BenchRow]``; run.py prints
the required ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.data import mnist_like, worker_batches
from repro.models import softmax
from repro.optim import inverse_time, momentum_sgd, sgd
from repro.train import RunConfig, train


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str
    #: exact wire cost of one call (bits), when the row measures a
    #: compression; None for rows where bits make no sense
    wire_bits: Optional[float] = None
    #: which dispatch route ran: "kernel" | "reference" | "packed" | ...
    path: Optional[str] = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def to_json(self, suite: str) -> dict:
        return {
            "suite": suite,
            "name": self.name,
            "us_per_call": round(self.us_per_call, 1),
            "wire_bits": self.wire_bits,
            "dispatch_path": self.path,
            "derived": self.derived,
        }


def convex_problem(n=4000, seed=0):
    x, y = mnist_like(n, seed=seed)
    cfg = softmax.SoftmaxConfig(l2=1.0 / n)
    params = softmax.init_params(jax.random.PRNGKey(0), cfg)

    def grad_fn(p, batch):
        return jax.value_and_grad(
            lambda pp: softmax.loss_fn(pp, batch, cfg)[0])(p)

    def eval_fn(p):
        feats = jnp.asarray(x[:1000])
        labels = jnp.asarray(y[:1000])
        loss, aux = softmax.loss_fn(p, {"features": feats, "labels": labels},
                                    cfg)
        return {"loss": loss, "accuracy": aux["accuracy"],
                "error": 1.0 - aux["accuracy"]}

    return x, y, cfg, params, grad_fn, eval_fn


def run_convex(op, H, T, *, R=15, b=8, asynchronous=False, seed=0,
               target_loss: Optional[float] = None, xi=60.0, a=100.0,
               inner="sgd", faults=None, fault_seed=None,
               staleness_weight="uniform"):
    """``faults``: a FaultSpec string ('max_delay=4,seed=1' /
    'preset:chaos') routes the run through the trainer's executed-
    staleness fault runtime (DESIGN.md §9) — payloads land at t+τ out
    of the in-flight queue instead of being modelled."""
    x, y, cfg, params, grad_fn, eval_fn = convex_problem()
    lr = inverse_time(xi=xi, a=a)
    batches = worker_batches(x, y, R, b, T, seed=seed)
    run_cfg = RunConfig(total_steps=T, R=R, H=H, log_every=25,
                        asynchronous=asynchronous, seed=seed,
                        target_loss=target_loss, eval_every=0,
                        faults=faults, fault_seed=fault_seed,
                        staleness_weight=staleness_weight)
    opt = momentum_sgd(0.9) if inner == "momentum" else sgd()
    t0 = time.time()
    state, hist = train(grad_fn, params, opt, op, lr, batches, run_cfg,
                        eval_fn=None)
    wall = time.time() - t0
    metrics = eval_fn(state.master)
    return {
        "final_loss": hist.loss[-1],
        "eval_loss": float(metrics["loss"]),
        "eval_error": float(metrics["error"]),
        "bits": hist.bits[-1],
        "bits_to_target": hist.bits_to_target,
        "steps_to_target": hist.steps_to_target,
        "us_per_step": wall / T * 1e6,
        "rounds": hist.rounds[-1],
    }

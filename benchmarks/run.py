"""Benchmark driver: one module per paper table/figure group.

Prints ``name,us_per_call,derived`` CSV (plus a header comment per
suite).  Roofline rows appear when artifacts/dryrun/ exists (run
``python -m repro.launch.dryrun --all`` first).
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time

SUITES = [
    ("operators", "benchmarks.bench_operators"),     # Fig 1a/4a companions
    ("convex", "benchmarks.bench_convex"),           # Fig 4-6
    ("async", "benchmarks.bench_async"),             # Fig 7
    ("nonconvex", "benchmarks.bench_nonconvex"),     # Fig 1-3
    ("scaled", "benchmarks.bench_scaled"),           # Fig 8 / App D
    ("roofline", "benchmarks.roofline"),             # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default=None,
                    choices=[s for s, _ in SUITES])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod_name in SUITES:
        if args.suite and name != args.suite:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run()
            for r in rows:
                print(r.csv(), flush=True)
            print(f"# suite {name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

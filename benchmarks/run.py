"""Benchmark driver: one module per paper table/figure group.

Prints ``name,us_per_call,derived`` CSV (plus a header comment per
suite).  Roofline rows appear when artifacts/dryrun/ exists (run
``python -m repro.launch.dryrun --all`` first).

``--json [DIR]`` additionally writes one machine-readable
``BENCH_<suite>.json`` per suite run — {suite, name, us_per_call,
wire_bits, dispatch_path, derived} rows — the format the committed
``benchmarks/BENCH_operators.json`` baseline and the CI regression
gate (``benchmarks/check_regression.py``) consume.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

SUITES = [
    ("operators", "benchmarks.bench_operators"),     # Fig 1a/4a companions
    ("convex", "benchmarks.bench_convex"),           # Fig 4-6
    ("async", "benchmarks.bench_async"),             # Fig 7
    ("nonconvex", "benchmarks.bench_nonconvex"),     # Fig 1-3
    ("scaled", "benchmarks.bench_scaled"),           # Fig 8 / App D
    ("scenarios", "benchmarks.bench_scenarios"),     # fleet scenario lab (§8)
    ("serve", "benchmarks.bench_serve"),             # serving engine (§11)
    ("roofline", "benchmarks.roofline"),             # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default=None,
                    choices=[s for s, _ in SUITES])
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="also write BENCH_<suite>.json into DIR "
                         "(default: current directory)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod_name in SUITES:
        if args.suite and name != args.suite:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run()
            for r in rows:
                print(r.csv(), flush=True)
            print(f"# suite {name} done in {time.time() - t0:.1f}s",
                  flush=True)
            if args.json is not None:
                os.makedirs(args.json, exist_ok=True)
                path = os.path.join(args.json, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump({"suite": name,
                               "rows": [r.to_json(name) for r in rows]},
                              f, indent=1)
                    f.write("\n")
                print(f"# wrote {path}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
artifacts/dryrun/*.json (run after repro.launch.dryrun)."""

from __future__ import annotations

import json
import os

from benchmarks.roofline import analyze, load_records


def dryrun_table(recs) -> str:
    hdr = ("| arch | shape | mesh | status | step | flops/chip | "
           "bytes/chip | coll MiB/chip | temp GiB | arg GiB | compile s |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] != "ok":
            reason = r.get("reason") or r.get("error", "")[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']} ({reason}) | | | | | | | |")
            continue
        for name, st in r["steps"].items():
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {name} | "
                f"{st['flops']:.3g} | {st['bytes_accessed']:.3g} | "
                f"{st['collectives']['total'] / 2**20:.1f} | "
                f"{st['memory']['temp_bytes'] / 2**30:.2f} | "
                f"{st['memory']['argument_bytes'] / 2**30:.2f} | "
                f"{st['compile_s']} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    hdr = ("| arch | shape | mesh | step | compute s | memory s | "
           "collective s | dominant | 6ND/HLO | hint |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        a = analyze(r)
        if a is None:
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['step']} | "
            f"{a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} | "
            f"{a['t_collective_s']:.3e} | **{a['dominant']}** | "
            f"{a['useful_ratio']:.2f} | {a['hint']} |")
    return "\n".join(lines)


def perf_compare(arch: str, shape: str, mesh: str, tags: list[str],
                 art_dir: str = "artifacts/dryrun") -> str:
    """Before/after table for hillclimb iterations (baseline + tags)."""
    rows = []
    base = f"{art_dir}/{arch}__{shape}__{mesh}.json"
    files = [("baseline", base)] + [
        (t, f"{art_dir}/{arch}__{shape}__{mesh}__{t}.json") for t in tags
    ]
    hdr = ("| iteration | step | compute s | memory s | collective s | "
           "temp GiB | arg GiB | coll GiB |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for tag, fn in files:
        if not os.path.exists(fn):
            lines.append(f"| {tag} | (missing) | | | | | | |")
            continue
        with open(fn) as f:
            rec = json.load(f)
        if rec["status"] != "ok":
            lines.append(f"| {tag} | ERROR {rec.get('error', '')[:50]} "
                         "| | | | | | |")
            continue
        a = analyze(rec)
        st = rec["steps"][a["step"]]
        lines.append(
            f"| {tag} | {a['step']} | {a['t_compute_s']:.3e} | "
            f"{a['t_memory_s']:.3e} | {a['t_collective_s']:.3e} | "
            f"{st['memory']['temp_bytes'] / 2**30:.2f} | "
            f"{st['memory']['argument_bytes'] / 2**30:.2f} | "
            f"{st['collectives']['total'] / 2**30:.2f} |")
    return "\n".join(lines)


def main():
    recs = load_records()
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()

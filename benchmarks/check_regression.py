"""CI benchmark-regression gate.

Compares a freshly generated ``BENCH_<suite>.json`` (``run.py --json``)
against the committed baseline and fails on per-row ``us_per_call``
regressions beyond the tolerance.

Raw microseconds are not comparable across machines (the baseline is
recorded on one container, CI runs on another), so the gate
self-calibrates: it computes the median current/baseline time ratio
over all shared rows — the machine-speed factor — and flags only rows
whose ratio exceeds ``median * tolerance``.  A uniform slowdown (colder
CI runner) passes; a single row that got slower *relative to its
peers* — the signature of a real dispatch/kernel regression — fails.

Rows whose baseline is faster than ``--min-us`` are reported but never
judged: at microsecond scale the 5-sample bench is jitter, not signal.

Rows present in the run but absent from the committed baseline (a PR
adding new bench coverage) are reported as ``NEW`` and skipped — only
rows that *disappear* from the run fail the gate.

Wire bits are machine-independent and compared to 1% relative — wide
enough for stochastic-quantizer nonzero counts to drift with the
(unpinned) jax PRNG version, narrow enough that any real ledger change
(fixed-k vs counted, a dropped scale field) trips it.

Exit status 0 = pass, 1 = regression or malformed input.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data["rows"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_<suite>.json")
    ap.add_argument("--current", required=True,
                    help="freshly generated BENCH_<suite>.json")
    ap.add_argument("--tolerance", type=float, default=1.25,
                    help="max allowed per-row slowdown vs the "
                         "median-calibrated baseline (1.25 = +25%%)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="baseline rows faster than this are informative "
                         "only (too noisy to gate)")
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    missing = sorted(set(base) - set(cur))
    if missing:
        print(f"FAIL: {len(missing)} baseline rows missing from current "
              f"run: {missing}")
        return 1
    # rows the run produced that the committed baseline predates (a PR
    # adding bench coverage): report them, never gate on them — they
    # become judged once the baseline is regenerated
    new = sorted(set(cur) - set(base))
    for name in new:
        us = cur[name].get("us_per_call")
        us_txt = f"{us:.1f}us" if us is not None else "-"
        print(f"  NEW {name}: {us_txt}  (not in baseline; skipped)")

    shared = sorted(set(base) & set(cur))
    ratios = {}
    for name in shared:
        b, c = base[name]["us_per_call"], cur[name]["us_per_call"]
        if b and b > 0 and c is not None:
            ratios[name] = c / b
    if not ratios:
        print("FAIL: no comparable rows")
        return 1
    speed = statistics.median(ratios.values())
    print(f"machine-speed factor (median us ratio over {len(ratios)} "
          f"rows): {speed:.3f}")

    # per-row delta summary table, worst calibrated ratio first, so a
    # regression (or a claimed speedup) is one glance away in CI logs
    failed = []
    entries = []
    for name, r in ratios.items():
        rel = r / speed
        gated = base[name]["us_per_call"] >= args.min_us
        slow = rel > args.tolerance
        mark = ("REGRESSION" if slow and gated
                else "slow(ungated)" if slow
                else "faster" if rel < 1 / args.tolerance and gated
                else "ok")
        entries.append((rel, name, r, gated, mark))
        if slow and gated:
            failed.append(name)
    width = max(len(n) for _, n, _, _, _ in entries)
    hdr = (f"  {'row'.ljust(width)}  {'base us':>10}  {'cur us':>10}  "
           f"{'delta':>8}  {'raw':>6}  {'calib':>6}  verdict")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    for rel, name, r, gated, mark in sorted(entries, reverse=True):
        b, c = base[name]["us_per_call"], cur[name]["us_per_call"]
        print(f"  {name.ljust(width)}  {b:>10.1f}  {c:>10.1f}  "
              f"{100 * (rel - 1):>+7.1f}%  {r:>6.2f}  {rel:>6.2f}  {mark}")

    bit_fails = []
    for name in shared:
        b, c = base[name].get("wire_bits"), cur[name].get("wire_bits")
        if b is None or c is None:
            continue
        if abs(c - b) > 1e-2 * max(abs(b), 1.0):
            bit_fails.append(f"{name}: wire_bits {b} -> {c}")
    for msg in bit_fails:
        print(f"  LEDGER CHANGE  {msg}")

    if failed or bit_fails:
        print(f"FAIL: {len(failed)} timing regression(s) beyond "
              f"x{args.tolerance} calibrated, {len(bit_fails)} wire-bit "
              "change(s)")
        return 1
    print("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
